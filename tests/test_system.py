"""End-to-end behaviour tests reproducing the paper's HEADLINE CLAIMS at CI
scale (scaled-down corpora; the paper's own metric is relative behaviour)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FCVIConfig, build, query, ground_truth_combined,
                        recall_at_k, BoxPredicate, post_filter_search,
                        ground_truth_filtered)
from repro.data.synthetic import (CorpusSpec, make_corpus, sample_queries,
                                  shift_filter_distribution)
from repro.index import flat as flat_mod


@pytest.fixture(scope="module")
def world():
    spec = CorpusSpec(n=6000, d=64, n_categories=6, n_numeric=2, seed=42)
    corpus = make_corpus(spec)
    q, fq = sample_queries(corpus, 24, seed=43)
    return corpus, q, fq


def test_paper_claim_high_recall(world):
    """Paper §6.2.2: FCVI holds ~95% recall. (We measure against the
    combined-score oracle, the paper's ranking target.)"""
    corpus, q, fq = world
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=16.0)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    _, ids = query(idx, jnp.asarray(q), jnp.asarray(fq), 100)
    qn, fqn = idx.transform.normalize(jnp.asarray(q), jnp.asarray(fq))
    _, ref = ground_truth_combined(idx.vectors_n, idx.filters_n, qn, fqn,
                                   100, cfg.lam)
    rec = float(recall_at_k(ids, ref))
    assert rec >= 0.93, f"recall@100 {rec}"


def test_paper_claim_beats_post_filter_on_selective_predicates(world):
    """Paper Table 1: FCVI recall >> post-filtering under selective filters."""
    corpus, q, fq = world
    spec = corpus.spec
    v, f = jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters)
    # selective predicate: one rare category
    rare = int(np.bincount(corpus.cat_labels, minlength=spec.n_categories).argmin())
    lo = np.full(spec.m, -np.inf, np.float32)
    hi = np.full(spec.m, np.inf, np.float32)
    lo[rare], hi[rare] = 0.5, 1.5        # one-hot dim == 1
    pred = BoxPredicate(low=jnp.asarray(lo), high=jnp.asarray(hi))
    sel = float(np.asarray(pred.mask(f)).mean())
    assert sel < 0.15

    k = 10
    _, ref = ground_truth_filtered(v, f, jnp.asarray(q), pred, k)
    # post-filter with bounded oversampling (the production constraint)
    _, post_ids = post_filter_search(flat_mod.build(v), f, jnp.asarray(q),
                                     pred, k, oversample=5)
    post_rec = float(recall_at_k(post_ids, ref))

    # FCVI with the predicate's soft encoding as the filter query
    fq_pred = np.broadcast_to(np.asarray(pred.to_filter_query(f)),
                              (q.shape[0], spec.m))
    cfg = FCVIConfig(alpha=2.0, lam=0.5, c=16.0)
    idx = build(v, f, cfg)
    _, fcvi_ids = query(idx, jnp.asarray(q), jnp.asarray(fq_pred.copy()), k)
    fcvi_rec = float(recall_at_k(fcvi_ids, ref))
    assert fcvi_rec > post_rec, (fcvi_rec, post_rec)


def test_paper_claim_stability_under_filter_shift(world):
    """Paper §6.3/Table 2 + §4.3: under a filter-distribution shift (no
    index rebuild) FCVI degrades boundedly with a STATIC k', and the
    adaptive-k' path (the serving engine's escalation) restores full recall
    — the paper's 'adaptively select k' based on filter selectivity'."""
    from repro.core import theory
    corpus, q, fq = world
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=16.0)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)

    def recall_on(queries, fqueries, k_prime=None):
        qq, ff = jnp.asarray(queries), jnp.asarray(fqueries)
        _, ids = query(idx, qq, ff, 10, k_prime=k_prime)
        qn, fqn = idx.transform.normalize(qq, ff)
        _, ref = ground_truth_combined(idx.vectors_n, idx.filters_n, qn, fqn,
                                       10, cfg.lam)
        return float(recall_at_k(ids, ref))

    base = recall_on(q, fq)
    assert base >= 0.9
    shifted = shift_filter_distribution(corpus)
    q2, fq2 = sample_queries(shifted, 24, seed=44)
    static_after = recall_on(q2, fq2)
    assert static_after >= base - 0.35          # bounded static degradation
    kp_adaptive = min(theory.k_prime(10, cfg.lam, 1.0, idx.size, cfg.c * 4),
                      idx.size)
    adaptive_after = recall_on(q2, fq2, k_prime=kp_adaptive)
    assert adaptive_after >= base - 0.02, (base, static_after, adaptive_after)
