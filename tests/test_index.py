"""Index backends: exactness (flat), recall (IVF/PQ), updates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.index import flat as flat_mod
from repro.index import ivf as ivf_mod
from repro.index import pq as pq_mod


@pytest.fixture(scope="module")
def corpus():
    r = np.random.default_rng(0)
    centers = r.normal(size=(16, 32)).astype(np.float32) * 3
    labels = r.integers(0, 16, 4096)
    x = (centers[labels] + 0.4 * r.normal(size=(4096, 32))).astype(np.float32)
    q = (centers[r.integers(0, 16, 16)]
         + 0.4 * r.normal(size=(16, 32))).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(q)


def test_flat_exact_matches_numpy(corpus):
    x, q = corpus
    idx = flat_mod.build(x)
    vals, ids = flat_mod.search(idx, q, 10)
    d2 = ((np.asarray(q)[:, None] - np.asarray(x)[None]) ** 2).sum(-1)
    ref_ids = np.argsort(d2, axis=1)[:, :10]
    assert (np.asarray(ids) == ref_ids).mean() > 0.99  # ties aside


def test_flat_blocked_equals_full(corpus):
    x, q = corpus
    idx = flat_mod.build(x)
    v1, i1 = flat_mod.search(idx, q, 8)
    v2, i2 = flat_mod.search(idx, q, 8, block_rows=512)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-5)
    assert (np.asarray(i1) == np.asarray(i2)).all()


def test_flat_masked(corpus):
    x, q = corpus
    idx = flat_mod.build(x)
    mask = jnp.arange(x.shape[0]) % 2 == 0
    _, ids = flat_mod.search_masked(idx, q, 10, mask)
    assert (np.asarray(ids) % 2 == 0).all()


def test_ivf_recall(corpus):
    x, q = corpus
    idx = ivf_mod.build(x, nlist=32)
    _, ids = ivf_mod.search(idx, q, 10, nprobe=8)
    _, ref = flat_mod.search(flat_mod.build(x), q, 10)
    hits = (np.asarray(ids)[:, :, None] == np.asarray(ref)[:, None, :]).any(1)
    assert hits.mean() > 0.8


def test_ivf_full_probe_is_exact(corpus):
    x, q = corpus
    idx = ivf_mod.build(x, nlist=8)
    _, ids = ivf_mod.search(idx, q, 10, nprobe=8)
    _, ref = flat_mod.search(flat_mod.build(x), q, 10)
    hits = (np.asarray(ids)[:, :, None] == np.asarray(ref)[:, None, :]).any(1)
    assert hits.mean() > 0.999


def test_ivf_add(corpus):
    x, q = corpus
    idx = ivf_mod.build(x[:3000], nlist=16)
    idx = ivf_mod.add(idx, x[3000:])
    assert idx.size == x.shape[0]
    _, ids = ivf_mod.search(idx, q, 10, nprobe=16)
    _, ref = flat_mod.search(flat_mod.build(x), q, 10)
    hits = (np.asarray(ids)[:, :, None] == np.asarray(ref)[:, None, :]).any(1)
    assert hits.mean() > 0.99


def test_pq_recall_and_reconstruct(corpus):
    x, q = corpus
    idx = pq_mod.build(x, m_subspaces=4, ksub=128)
    _, ids = pq_mod.search(idx, q, 20)
    _, ref1 = flat_mod.search(flat_mod.build(x), q, 1)
    # PQ@20 must cover the exact top-1 on clustered data (ANN contract:
    # candidates feed an exact re-ranker, see FCVI's rescore stage)
    hits = (np.asarray(ids)[:, :, None] == np.asarray(ref1)[:, None, :]).any(1)
    assert hits.mean() > 0.6
    rec = pq_mod.reconstruct(idx, jnp.arange(16))
    err = np.linalg.norm(np.asarray(rec) - np.asarray(x[:16]), axis=1)
    base = np.linalg.norm(np.asarray(x[:16]), axis=1)
    assert (err / base).mean() < 0.4  # codes reconstruct meaningfully
