"""Int8 storage rung: quantization edge cases and end-to-end exactness.

The contract under test (see ``repro.index.quant``): int8 slabs hold per-row
symmetric codes plus one fp32 scale; scoring streams the codes and scales the
matmul OUTPUT so accumulation stays fp32; the exact-refine / combined-score
re-rank always runs on fp32 rows. Consequences pinned here:

  * degenerate rows (constant, all-zero, saturating outliers) quantize to
    finite codes/scales and never produce NaN scores;
  * empty IVF lists coexist with int8 grouped slabs;
  * the dedup kernel agrees with the jnp reference bit-for-bit with scales;
  * ``ops.rescore`` accepts fp32 / bf16 / int8-dequantized candidate tiles
    and accumulates fp32 (the dtype matrix);
  * the engine's FINAL top-k ids and scores from int8 storage are identical
    to the fp32 reference — meshless here, sharded/routed/degraded in the
    slow subprocess cases — and survive save/restore onto a different mesh.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FCVIConfig, build, fcvi
from repro.data.synthetic import CorpusSpec, make_corpus, sample_queries
from repro.index import quant
from repro.kernels import ops
from repro.kernels.ivf_score import dedup_probes
from repro.serve.engine import EngineConfig, FCVIEngine

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_in_subprocess(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.fixture(scope="module")
def data():
    spec = CorpusSpec(n=1000, d=64, n_categories=5, n_numeric=3, seed=2)
    corpus = make_corpus(spec)
    q, fq = sample_queries(corpus, 8, seed=3)
    return corpus, np.asarray(q), np.asarray(fq)


# ---------------------------------------------------------------- quant unit


def test_constant_rows_zero_range_guard():
    """Zero value range must not produce a 0 scale (0/0 codes): the scale is
    clamped to 1.0 and the codes are exactly zero."""
    x = jnp.stack([jnp.zeros(16), jnp.full(16, 3.5), jnp.full(16, -0.25)])
    codes, scales = quant.quantize_rows(x)
    assert np.isfinite(np.asarray(scales)).all()
    assert np.asarray(scales)[0] == 1.0            # all-zero row
    y = np.asarray(quant.dequantize_rows(codes, scales))
    assert np.isfinite(y).all()
    np.testing.assert_array_equal(y[0], np.zeros(16))
    # constant rows round-trip exactly: every element IS the row max
    np.testing.assert_allclose(y[1], 3.5, rtol=0, atol=0)
    np.testing.assert_allclose(y[2], -0.25, rtol=0, atol=0)


def test_saturating_outlier_rows_never_clip():
    """The scale is derived from the row max, so |codes| <= 127 by
    construction even for extreme outliers — no wraparound, no inf."""
    r = np.random.default_rng(0)
    x = r.normal(size=(8, 32)).astype(np.float32)
    x[:, 0] = [1e30, -1e30, 1e8, 127.0, 1e-30, 5e37, -5e37, 0.0]
    codes, scales = quant.quantize_rows(jnp.asarray(x))
    c = np.asarray(codes, np.int32)
    assert np.abs(c).max() <= 127
    assert np.isfinite(np.asarray(scales)).all()
    y = np.asarray(quant.dequantize_rows(codes, scales))
    assert np.isfinite(y).all()
    # the outlier element itself reconstructs to within one quantization step
    np.testing.assert_allclose(y[2, 0], 1e8, rtol=1 / 127)


def test_empty_input_quantizes():
    codes, scales = quant.quantize_rows(jnp.zeros((0, 16)))
    assert codes.shape == (0, 16) and scales.shape == (0,)


# ------------------------------------------------------------ index behavior


def test_empty_ivf_lists_with_int8(data):
    """More centroids than natural clusters leaves some IVF lists empty;
    their all-pad grouped rows must quantize benignly (scale-1 zero rows)
    and the int8 results must match fp32 exactly after refine."""
    corpus, q, fq = data
    # 3 distinct points repeated -> kmeans with 16 centroids leaves most
    # lists empty
    base = np.asarray(corpus.vectors[:3])
    vecs = np.tile(base, (20, 1)).astype(np.float32)
    filt = np.tile(np.asarray(corpus.filters[:3]), (20, 1)).astype(np.float32)
    out = {}
    for st in ("float32", "int8"):
        cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend="ivf", nlist=16,
                         nprobe=16, storage_dtype=st)
        idx = build(jnp.asarray(vecs), jnp.asarray(filt), cfg)
        assert int(np.asarray(idx.backend.list_sizes).min()) == 0
        out[st] = fcvi.query(idx, jnp.asarray(q), jnp.asarray(fq), 5)
    (s0, i0), (s1, i1) = out["float32"], out["int8"]
    assert np.isfinite(np.asarray(s0)).all()
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def test_dedup_kernel_int8_parity(data):
    """The probe-major dedup kernel must agree with the jnp reference when
    streaming int8 codes with per-row scales. Raw candidate scores are
    allclose, not bit-equal — the kernel scales the dot OUTPUT (one multiply
    per score) while the reference dequantizes rows before the dot; the
    engine's FINAL top-k is still bit-identical across both because exact
    refine re-scores candidates on fp32 rows (pinned below)."""
    corpus, q, fq = data
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters),
                FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend="ivf",
                           nlist=16, nprobe=4, storage_dtype="int8"))
    bk = idx.backend
    qn, fqn = idx.transform.normalize(jnp.asarray(q), jnp.asarray(fq))
    q_t = idx.transform.apply_normalized(qn, fqn)
    d2 = (jnp.sum(q_t**2, 1, keepdims=True)
          - 2 * q_t @ bk.centroids.T + jnp.sum(bk.centroids**2, 1))
    probes = jax.lax.top_k(-d2, 4)[1].astype(jnp.int32)
    uniq, member = dedup_probes(probes, bk.nlist)
    va, ia = ops.ivf_score_topk_dedup(bk.grouped, bk.grouped_sq, bk.valid,
                                      uniq, member, q_t, 10,
                                      scales=bk.grouped_scales,
                                      use_pallas=True)
    vb, ib = ops.ivf_score_topk_dedup(bk.grouped, bk.grouped_sq, bk.valid,
                                      uniq, member, q_t, 10,
                                      scales=bk.grouped_scales,
                                      use_pallas=False)
    np.testing.assert_allclose(np.asarray(va), np.asarray(vb),
                               rtol=1e-5, atol=1e-4)
    # same candidates in the same order (no near-tie swaps at this scale)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))


def test_rescore_dtype_matrix():
    """``ops.rescore`` accepts bf16 / int8-dequantized candidate tiles: both
    the kernel and the jnp reference cast up front and accumulate fp32, so
    each reduced-precision input scores IDENTICALLY to its fp32-cast self
    (the fp32-accumulation contract), and kernel/reference agree to fp32
    round-off at every rung (their cosine formulations differ by a ULP)."""
    r = np.random.default_rng(1)
    b, kp, d, m = 8, 16, 32, 8
    cv = r.normal(size=(b, kp, d)).astype(np.float32)
    cf = r.normal(size=(b, kp, m)).astype(np.float32)
    qn = r.normal(size=(b, d)).astype(np.float32)
    fqn = r.normal(size=(b, m)).astype(np.float32)

    def variants(x):
        codes, scales = quant.quantize_rows(jnp.asarray(x))
        deq = quant.dequantize_rows(codes, scales)
        return {"float32": jnp.asarray(x),
                "bfloat16": jnp.asarray(x).astype(jnp.bfloat16),
                "int8-dequant": deq}

    for name, v in variants(cv).items():
        f = variants(cf)[name]
        kern = ops.rescore(v, f, jnp.asarray(qn), jnp.asarray(fqn), 0.6,
                           use_pallas=True)
        ref = ops.rescore(v, f, jnp.asarray(qn), jnp.asarray(fqn), 0.6,
                          use_pallas=False)
        assert kern.dtype == jnp.float32, name
        np.testing.assert_allclose(np.asarray(kern), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)
        # reduced-precision inputs score exactly as their fp32 upcasts
        up = ops.rescore(v.astype(jnp.float32), f.astype(jnp.float32),
                         jnp.asarray(qn), jnp.asarray(fqn), 0.6,
                         use_pallas=True)
        np.testing.assert_array_equal(np.asarray(kern), np.asarray(up))


# ------------------------------------------------------------- engine final


@pytest.mark.parametrize("backend", ["flat", "ivf"])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_int8_final_topk_matches_fp32(data, backend, use_pallas):
    """Acceptance: the engine's final top-k ids AND scores from int8 storage
    are identical to the fp32 reference — the exact-refine pass re-scores
    candidates on fp32 rows, so quantization only perturbs candidate
    GENERATION, and the over-retrieval margin absorbs that."""
    corpus, q, fq = data
    out = {}
    for st in ("float32", "int8"):
        cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend=backend,
                         nlist=16, nprobe=8, use_pallas=use_pallas,
                         storage_dtype=st)
        idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters),
                    cfg)
        eng = FCVIEngine(idx, EngineConfig(k=5, batch_size=16))
        out[st] = tuple(map(np.asarray, eng.search(q, fq)))
    np.testing.assert_array_equal(out["float32"][1], out["int8"][1])
    np.testing.assert_array_equal(out["float32"][0], out["int8"][0])


@pytest.mark.slow
def test_int8_sharded_routed_degraded_matches_fp32():
    """Int8 == fp32 holds through every serving topology: 8-shard dense,
    filter-routed (cluster placement) and degraded (1 dead shard)."""
    run_in_subprocess("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import FCVIConfig, build
    from repro.data.synthetic import CorpusSpec, make_corpus, sample_queries
    from repro.launch.mesh import make_mesh
    from repro.serve.engine import EngineConfig, FCVIEngine

    assert len(jax.devices()) == 8
    spec = CorpusSpec(n=1000, d=64, n_categories=5, n_numeric=3, seed=2)
    corpus = make_corpus(spec)
    q, fq = sample_queries(corpus, 5, seed=3)
    q, fq = np.asarray(q), np.asarray(fq)
    mesh = make_mesh((8, 1), ("data", "model"))

    def res(backend, st, **kw):
        dead = kw.pop("dead", None)
        cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend=backend,
                         nlist=16, nprobe=4, storage_dtype=st)
        idx = build(jnp.asarray(corpus.vectors),
                    jnp.asarray(corpus.filters), cfg)
        eng = FCVIEngine(idx, EngineConfig(k=5, batch_size=16),
                         mesh=mesh, **kw)
        if dead:
            eng.health.mark_dead(dead)
        return tuple(np.asarray(x) for x in eng.search(q, fq))

    for backend in ("flat", "ivf"):
        pl = "cluster" if backend == "flat" else "contiguous"
        for kw in (dict(),
                   dict(routing="routed", placement=pl),
                   dict(dead=[1])):
            a = res(backend, "float32", **dict(kw))
            b = res(backend, "int8", **dict(kw))
            assert (a[1] == b[1]).all(), (backend, kw)
            assert (a[0] == b[0]).all(), (backend, kw)
    print("int8 topology matrix OK")
    """)


@pytest.mark.slow
def test_int8_save_restore_onto_different_mesh():
    """An int8 engine checkpointed from an 8-device mesh must restore onto a
    2-device mesh (and meshless) with the quantized slabs intact and serve
    identical results — including pending delta rows."""
    run_in_subprocess("""
    import numpy as np, jax, jax.numpy as jnp, tempfile
    from repro.core import FCVIConfig, build
    from repro.data.synthetic import CorpusSpec, make_corpus, sample_queries
    from repro.launch.mesh import make_mesh
    from repro.serve.engine import EngineConfig, FCVIEngine

    assert len(jax.devices()) == 8
    spec = CorpusSpec(n=1000, d=64, n_categories=5, n_numeric=3, seed=2)
    corpus = make_corpus(spec)
    q, fq = sample_queries(corpus, 5, seed=3)
    q, fq = np.asarray(q), np.asarray(fq)

    for backend in ("flat", "ivf"):
        cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend=backend,
                         nlist=16, nprobe=4, storage_dtype="int8")
        idx = build(jnp.asarray(corpus.vectors),
                    jnp.asarray(corpus.filters), cfg)
        e8 = FCVIEngine(idx, EngineConfig(k=5, batch_size=16,
                                          compact_threshold=256),
                        mesh=make_mesh((8, 1), ("data", "model")))
        r = np.random.default_rng(0)
        e8.insert(r.normal(size=(20, spec.d)).astype(np.float32),
                  corpus.filters[:20].copy())
        want = tuple(np.asarray(x) for x in e8.search(q, fq))
        tmp = tempfile.mkdtemp()
        e8.save(tmp, step=1)
        for mesh in (make_mesh((2, 1), ("data", "model")), None):
            er = FCVIEngine.restore(tmp, mesh=mesh)
            assert er.index.config.storage_dtype == "int8", backend
            got = tuple(np.asarray(x) for x in er.search(q, fq))
            assert (want[1] == got[1]).all(), (backend, mesh)
            assert (want[0] == got[0]).all(), (backend, mesh)
    print("int8 elastic restore OK")
    """)
