"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(r, shape, dtype):
    x = r.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.mark.parametrize("n,d,m", [(256, 64, 4), (512, 128, 8), (128, 96, 3),
                                   (384, 256, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_transform(n, d, m, dtype):
    r = np.random.default_rng(n + m)
    v = _rand(r, (n, d), dtype)
    f = _rand(r, (n, m), dtype)
    P = ref.partition_matrix(d, m)
    mv, sv = jnp.full((d,), 0.2), jnp.full((d,), 1.3)
    mf, sf = jnp.full((m,), -0.1), jnp.full((m,), 0.8)
    got = ops.fused_transform(v, f, P, 2.0, mv, sv, mf, sf, block_rows=128)
    want = ref.ref_fused_transform(v, f, P, 2.0, mv, sv, mf, sf)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n,d,q,k", [(512, 64, 64, 8), (256, 128, 128, 16),
                                     (1024, 32, 64, 32)])
def test_score_topk(n, d, q, k):
    r = np.random.default_rng(n + k)
    corpus = _rand(r, (n, d), jnp.float32)
    queries = _rand(r, (q, d), jnp.float32)
    sq = jnp.sum(corpus * corpus, -1)
    v1, i1 = ops.score_topk(corpus, sq, queries, k, block_rows=128, block_q=64)
    v2, i2 = ref.ref_score_topk(corpus, sq, queries, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4,
                               atol=1e-4)
    assert (np.asarray(i1) == np.asarray(i2)).mean() > 0.999


@pytest.mark.parametrize("b,kp,d,m", [(8, 32, 64, 4), (16, 64, 128, 8)])
def test_rescore(b, kp, d, m):
    r = np.random.default_rng(b)
    cv = _rand(r, (b, kp, d), jnp.float32)
    cf = _rand(r, (b, kp, m), jnp.float32)
    qn = _rand(r, (b, d), jnp.float32)
    fqn = _rand(r, (b, m), jnp.float32)
    got = ops.rescore(cv, cf, qn, fqn, 0.35, block_b=4)
    want = ref.ref_rescore(cv, cf, qn, fqn, 0.35)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-5)


@pytest.mark.parametrize("nlist,maxl,d,nprobe,k",
                         [(8, 64, 64, 3, 8), (16, 128, 32, 5, 16)])
def test_ivf_score_topk(nlist, maxl, d, nprobe, k):
    r = np.random.default_rng(nlist)
    grouped = _rand(r, (nlist, maxl, d), jnp.float32)
    gsq = jnp.sum(grouped * grouped, -1)
    valid = jnp.asarray((r.random((nlist, maxl)) > 0.15).astype(np.float32))
    probes = jnp.asarray(r.choice(nlist, nprobe, replace=False).astype(np.int32))
    qv = _rand(r, (d,), jnp.float32)
    v1, i1 = ops.ivf_score_topk(grouped, gsq, valid, probes, qv, k)
    v2, i2 = ref.ref_ivf_score_topk(grouped, gsq, valid > 0.5, probes, qv, k)
    # kernel drops the ||q||^2 constant: compare shifted
    q2 = float(jnp.sum(qv * qv))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2) + q2,
                               rtol=1e-4, atol=1e-4)
    assert (np.asarray(i1) == np.asarray(i2)).all()


@pytest.mark.parametrize("b,nlist,maxl,d,nprobe,k",
                         [(4, 8, 64, 64, 3, 8), (6, 16, 128, 32, 5, 16)])
def test_ivf_score_topk_batch(b, nlist, maxl, d, nprobe, k):
    """Batched probed-slab kernel vs vmapped oracle (kernel convention)."""
    r = np.random.default_rng(b + nlist)
    grouped = _rand(r, (nlist, maxl, d), jnp.float32)
    gsq = jnp.sum(grouped * grouped, -1)
    valid = jnp.asarray((r.random((nlist, maxl)) > 0.15).astype(np.float32))
    probes = jnp.asarray(np.stack(
        [r.choice(nlist, nprobe, replace=False) for _ in range(b)]
    ).astype(np.int32))
    qs = _rand(r, (b, d), jnp.float32)
    v1, i1 = ops.ivf_score_topk_batch(grouped, gsq, valid, probes, qs, k)
    v2, i2 = ops.ivf_score_topk_batch(grouped, gsq, valid, probes, qs, k,
                                      use_pallas=False)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                               rtol=1e-4, atol=1e-4)
    assert (np.asarray(i1) == np.asarray(i2)).all()


@pytest.mark.parametrize("b,nlist,maxl,d,nprobe,k",
                         [(4, 8, 64, 64, 3, 8), (6, 16, 128, 32, 5, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ivf_score_topk_dedup(b, nlist, maxl, d, nprobe, k, dtype):
    """Probe-major dedup kernel vs its oracle AND the per-probe batch kernel:
    deduplicating shared slabs must not change any result."""
    from repro.kernels.ivf_score import dedup_probes

    r = np.random.default_rng(b + nlist)
    grouped = _rand(r, (nlist, maxl, d), dtype)
    gsq = jnp.sum(grouped.astype(jnp.float32) ** 2, -1)
    valid = jnp.asarray((r.random((nlist, maxl)) > 0.15).astype(np.float32))
    probes = jnp.asarray(np.stack(
        [r.choice(nlist, nprobe, replace=False) for _ in range(b)]
    ).astype(np.int32))
    qs = _rand(r, (b, d), jnp.float32)
    uniq, member = dedup_probes(probes, nlist)
    assert uniq.shape[0] == min(nlist, b * nprobe)
    v1, i1 = ops.ivf_score_topk_dedup(grouped, gsq, valid, uniq, member, qs, k)
    v2, i2 = ops.ivf_score_topk_dedup(grouped, gsq, valid, uniq, member, qs, k,
                                      use_pallas=False)
    vb, ib = ops.ivf_score_topk_batch(grouped, gsq, valid, probes, qs, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                               rtol=1e-4, atol=1e-4)
    assert (np.asarray(i1) == np.asarray(i2)).all()
    np.testing.assert_allclose(np.asarray(v1), np.asarray(vb),
                               rtol=1e-4, atol=1e-4)
    assert (np.asarray(i1) == np.asarray(ib)).all()


def test_score_topk_padded_arbitrary_shapes():
    """Padded dispatch: corpus rows and query counts off the tile multiples."""
    r = np.random.default_rng(3)
    corpus = _rand(r, (100, 32), jnp.float32)
    queries = _rand(r, (5, 32), jnp.float32)
    sq = jnp.sum(corpus * corpus, -1)
    v1, i1 = ops.score_topk_padded(corpus, sq, queries, 7)
    v2, i2 = ref.ref_score_topk(corpus, sq, queries, 7)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                               rtol=1e-4, atol=1e-4)
    assert (np.asarray(i1) == np.asarray(i2)).all()


@pytest.mark.parametrize("n,M,ksub,q", [(500, 4, 32, 3), (512, 8, 64, 5)])
def test_pq_score_batch(n, M, ksub, q):
    """Multi-query ADC kernel, incl. row counts that need padding."""
    r = np.random.default_rng(n + q)
    codes = jnp.asarray(r.integers(0, ksub, (n, M)).astype(np.int32))
    luts = jnp.asarray(r.random((q, M, ksub)).astype(np.float32))
    got = ops.pq_score_batch(codes, luts, block_rows=128)
    want = ops.pq_score_batch(codes, luts, use_pallas=False)
    assert got.shape == (q, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,M,ksub", [(512, 8, 64), (1024, 16, 256),
                                      (256, 4, 16)])
def test_pq_score(n, M, ksub):
    r = np.random.default_rng(M)
    codes = jnp.asarray(r.integers(0, ksub, (n, M)).astype(np.int32))
    lut = jnp.asarray(r.random((M, ksub)).astype(np.float32))
    got = ops.pq_score(codes, lut, block_rows=128)
    want = ref.ref_pq_score(codes, lut)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_ops_fallback_matches_pallas():
    """use_pallas=False (oracle path) and kernels must agree bit-for-bit-ish."""
    r = np.random.default_rng(9)
    corpus = _rand(r, (256, 64), jnp.float32)
    q = _rand(r, (32, 64), jnp.float32)
    sq = jnp.sum(corpus * corpus, -1)
    v1, i1 = ops.score_topk(corpus, sq, q, 8)
    v2, i2 = ops.score_topk(corpus, sq, q, 8, use_pallas=False)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4,
                               atol=1e-4)
    assert (np.asarray(i1) == np.asarray(i2)).all()


@pytest.mark.parametrize("q,M,dsub,ksub", [(64, 8, 8, 64), (5, 4, 16, 32),
                                           (130, 2, 8, 16)])
def test_pq_lut_qdot(q, M, dsub, ksub):
    """LUT-construction cross-term kernel vs the einsum oracle (incl. query
    counts that are not a multiple of the kernel's query block)."""
    r = np.random.default_rng(q + ksub)
    qs = _rand(r, (q, M, dsub), jnp.float32)
    cb = _rand(r, (M, ksub, dsub), jnp.float32)
    got = ops.pq_lut_qdot(qs, cb, block_q=64)
    want = ref.ref_pq_lut_qdot(qs, cb)
    assert got.shape == (q, M, ksub)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
