"""Training loop, checkpointing (elastic restore), fault-tolerance policies."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.checkpoint import ckpt
from repro.data.tokens import MarkovTokens, TokenSpec
from repro.distributed import fault
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.train import loop as train_loop
from repro.train import optimizer as opt


def test_loss_decreases_on_markov_data():
    cfg = reduced(get_config("gemma3-1b"))
    cfg = dataclasses.replace(cfg, n_layers=2, pattern=("local", "attn"))
    adamw = opt.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                            weight_decay=0.0)
    step = jax.jit(train_loop.make_train_step(cfg, adamw))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    stream = MarkovTokens(TokenSpec(vocab_size=cfg.vocab_size, batch=8,
                                    seq_len=64, seed=0, branching=4))
    losses = []
    for i, batch in zip(range(40), stream):
        params, state, m = step(params, state, {"tokens": jnp.asarray(batch["tokens"])})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, f"{losses[0]:.3f} -> {losses[-1]:.3f}"


def test_microbatch_accumulation_matches():
    cfg = reduced(get_config("starcoder2-7b"))
    cfg = dataclasses.replace(cfg, n_layers=2)
    adamw = opt.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step1 = jax.jit(train_loop.make_train_step(cfg, adamw, n_micro=1))
    step2 = jax.jit(train_loop.make_train_step(cfg, adamw, n_micro=2))
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0,
                                          cfg.vocab_size)}
    p1, _, m1 = step1(params, opt.init(params), batch)
    p2, _, m2 = step2(params, opt.init(params), batch)
    # same data -> nearly identical update (bf16 noise only)
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-3, f"micro-accum drift {d}"


def test_adamw_schedule():
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    assert float(opt.schedule(cfg, jnp.asarray(0))) == 0.0
    assert float(opt.schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(opt.schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1)


def test_grad_clip():
    cfg = opt.AdamWConfig(grad_clip=1.0, lr=0.1, weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    grads = {"w": jnp.full((4,), 100.0)}
    state = opt.init(params)
    _, _, m = opt.update(cfg, grads, state, params)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": [{"b": jnp.ones((2, 2), jnp.bfloat16)},
                       {"b": jnp.zeros((2, 2), jnp.bfloat16)}]}
    ckpt.save(str(tmp_path), 7, tree, metadata={"mesh": [4, 2]})
    out, step, meta = ckpt.restore(str(tmp_path), tree)
    assert step == 7 and meta["mesh"] == [4, 2]
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_gc_and_latest(tmp_path):
    tree = {"x": jnp.ones((2,))}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree, keep=3)
    assert ckpt.all_steps(str(tmp_path)) == [3, 4, 5]
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    ckpt.save(str(tmp_path), 1, {"x": jnp.ones((4,))})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"x": jnp.ones((5,))})


def test_checkpoint_elastic_restore_resharded(tmp_path):
    """Restore onto a different device layout (elastic restart path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    ckpt.save(str(tmp_path), 3, tree)
    mesh = make_mesh((1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out, _, _ = ckpt.restore(str(tmp_path), tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


# ---------------------------------------------------------------------------
# Fault tolerance policies
# ---------------------------------------------------------------------------

def test_straggler_detection():
    hb = fault.HeartbeatTracker(n_hosts=8, straggler_z=2.0,
                                straggler_patience=3)
    for step in range(6):
        for h in range(8):
            t = 1.0 if h != 3 else 3.0  # host 3 consistently 3x slower
            hb.record(h, step, t)
        stragglers = hb.stragglers()
    assert stragglers == [3]


def test_failure_detection_and_restart_plan():
    hb = fault.HeartbeatTracker(n_hosts=4, timeout_steps=2)
    for step in range(5):
        for h in range(4):
            if h == 2 and step >= 2:
                continue  # host 2 dies at step 2
            hb.record(h, step, 1.0)
    dead = hb.failures(current_step=5)
    assert dead == [2]
    hb.mark_dead(dead)
    # 4 hosts x 64 devices; lose one -> 192 devices, TP=16 keeps 12 data rows
    plan = fault.plan_restart(n_alive_devices=192, model_parallel=16,
                              old_mesh_shape=(16, 16), dropped_hosts=dead)
    assert plan.mesh_shape == (12, 16)
    assert plan.n_devices == 192
    assert plan.batch_scale == pytest.approx(12 / 16)


def test_restart_infeasible():
    assert fault.plan_restart(8, 16, (16, 16), [0]) is None


def test_microbatch_reassignment_covers_all():
    plan = fault.reassign_microbatches(16, alive_hosts=[0, 1, 3])
    assert set(plan.keys()) == set(range(16))
    assert set(plan.values()) == {0, 1, 3}
    loads = [list(plan.values()).count(h) for h in (0, 1, 3)]
    assert max(loads) - min(loads) <= 1

def test_single_host_fleet_never_straggles():
    """A fleet of one has no peers to be slower than: fleet sd degenerates
    and the z-score must not flag the only host."""
    hb = fault.HeartbeatTracker(n_hosts=1, straggler_patience=2)
    for step in range(6):
        hb.record(0, step, 5.0)
        assert hb.stragglers() == []


def test_all_hosts_straggling_flags_none():
    """Uniform slowness is not straggling — everyone IS the fleet."""
    hb = fault.HeartbeatTracker(n_hosts=4, straggler_z=2.0,
                                straggler_patience=2)
    for step in range(6):
        for h in range(4):
            hb.record(h, step, 10.0)
    assert hb.stragglers() == []


def test_straggler_recovering_before_patience_not_flagged():
    """The persistence count resets when the host rejoins the fleet pace
    before ``straggler_patience`` consecutive slow checks accumulate."""
    hb = fault.HeartbeatTracker(n_hosts=4, alpha=1.0, straggler_z=1.4,
                                straggler_patience=3)
    flagged = []
    for step, slow in enumerate([True, True, False, True, True, False]):
        for h in range(4):
            t = 3.0 if (h == 1 and slow) else 1.0
            hb.record(h, step, t)
        flagged += hb.stragglers()
    assert flagged == []


def test_heartbeat_timeout_on_step_zero():
    """A fresh tracker at step 0 has nobody silent — the never-recorded
    sentinel must not count as ``timeout_steps`` of silence."""
    hb = fault.HeartbeatTracker(n_hosts=4, timeout_steps=2)
    assert hb.failures(current_step=0) == []
    assert hb.failures(current_step=2) == []       # within the timeout
    assert hb.failures(current_step=3) == [0, 1, 2, 3]  # now truly silent


def test_mark_alive_resurrects_with_clean_straggler_record():
    hb = fault.HeartbeatTracker(n_hosts=2, straggler_patience=1,
                                straggler_z=0.5)
    for step in range(3):
        hb.record(0, step, 1.0)
        hb.record(1, step, 9.0)
    assert hb.stragglers() == [1]
    hb.mark_dead([1])
    assert hb.alive_hosts() == [0]
    hb.mark_alive([1])
    assert hb.alive_hosts() == [0, 1]
    assert hb._strag_count[1] == 0


# ---------------------------------------------------------------------------
# Checkpoint integrity (per-array checksums, corrupt-step fallback)
# ---------------------------------------------------------------------------

def test_checkpoint_checksums_recorded(tmp_path):
    import json
    import zlib

    tree = {"a": jnp.arange(6, dtype=jnp.float32)}
    ckpt.save(str(tmp_path), 1, tree)
    manifest = json.loads(
        (tmp_path / "step_00000001" / "manifest.json").read_text())
    assert manifest["checksums"]["a"] == zlib.crc32(
        np.arange(6, dtype=np.float32).tobytes())


def test_corrupt_fallback_to_newest_intact(tmp_path):
    tree1 = {"a": jnp.ones((4,))}
    tree2 = {"a": jnp.full((4,), 2.0)}
    ckpt.save(str(tmp_path), 1, tree1)
    ckpt.save(str(tmp_path), 2, tree2)
    npz = tmp_path / "step_00000002" / "arrays.npz"
    npz.write_bytes(npz.read_bytes()[: npz.stat().st_size // 2])  # torn
    with pytest.warns(UserWarning, match="skipping corrupt"):
        out, step, _ = ckpt.load(str(tmp_path))
    assert step == 1
    np.testing.assert_array_equal(out["a"], np.ones((4,)))


def test_corrupt_explicit_step_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.ones((4,))})
    mpath = tmp_path / "step_00000001" / "manifest.json"
    mpath.write_text("{ not json")
    with pytest.raises(ckpt.CheckpointCorruptError):
        ckpt.load(str(tmp_path), step=1)


def test_checksum_mismatch_raises(tmp_path):
    import json

    ckpt.save(str(tmp_path), 1, {"a": jnp.ones((4,))})
    mpath = tmp_path / "step_00000001" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["checksums"]["a"] ^= 1
    mpath.write_text(json.dumps(manifest))
    with pytest.raises(ckpt.CheckpointCorruptError, match="checksum"):
        ckpt.load(str(tmp_path), step=1)


def test_legacy_manifest_without_checksums_loads(tmp_path):
    """Checkpoints written before integrity checksums existed still load
    (verification is skipped, not failed)."""
    import json

    ckpt.save(str(tmp_path), 1, {"a": jnp.arange(4, dtype=jnp.float32)})
    mpath = tmp_path / "step_00000001" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    del manifest["checksums"]
    mpath.write_text(json.dumps(manifest))
    out, step, _ = ckpt.load(str(tmp_path))
    assert step == 1
    np.testing.assert_array_equal(out["a"], np.arange(4, dtype=np.float32))
