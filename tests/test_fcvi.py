"""End-to-end FCVI behaviour (Algorithm 1) against the combined-score oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FCVIConfig, build, query, multi_probe_query,
                        ground_truth_combined, recall_at_k, extend)
from repro.data.synthetic import CorpusSpec, make_corpus, sample_queries


@pytest.fixture(scope="module")
def data():
    spec = CorpusSpec(n=4000, d=64, n_vec_clusters=16, n_categories=5,
                      n_numeric=3, seed=0)
    corpus = make_corpus(spec)
    q, fq = sample_queries(corpus, 16, seed=1)
    return corpus, jnp.asarray(q), jnp.asarray(fq)


def _recall(index, q, fq, k=10):
    _, ids = query(index, q, fq, k)
    qn, fqn = index.transform.normalize(q, fq)
    _, true_ids = ground_truth_combined(index.vectors_n, index.filters_n,
                                        qn, fqn, k, index.config.lam)
    return float(recall_at_k(ids, true_ids))


@pytest.mark.parametrize("backend", ["flat", "ivf", "pq"])
def test_backend_recall(data, backend):
    corpus, q, fq = data
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=16.0, backend=backend,
                     nlist=32, nprobe=16, pq_m=8, pq_ksub=64)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    rec = _recall(idx, q, fq)
    floor = {"flat": 0.9, "ivf": 0.75, "pq": 0.4}[backend]
    assert rec >= floor, f"{backend} recall {rec}"


@pytest.mark.parametrize("mode", ["partition", "cluster", "embedding"])
def test_transform_modes(data, mode):
    corpus, q, fq = data
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=16.0, mode=mode, n_clusters=8)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    assert _recall(idx, q, fq) >= 0.8


def test_auto_alpha_thm54(data):
    corpus, q, fq = data
    cfg = FCVIConfig(lam=0.2, auto_alpha=True, c=16.0)
    assert cfg.resolved_alpha() == pytest.approx(2.0, rel=1e-3)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    assert _recall(idx, q, fq) >= 0.8


def test_lambda_extremes(data):
    """lam=1 ranks purely by vector similarity; lam->0 by filter similarity.

    At small lam the combined score has massive TIES (filter-similarity
    plateaus), so id-recall is ill-defined — compare achieved SCORES against
    the oracle's instead.
    """
    corpus, q, fq = data
    v, f = jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters)
    for lam in (0.999, 0.2):
        cfg = FCVIConfig(alpha=1.0, lam=lam, c=16.0)
        idx = build(v, f, cfg)
        scores, _ = query(idx, q, fq, 10)
        qn, fqn = idx.transform.normalize(q, fq)
        oracle_scores, _ = ground_truth_combined(
            idx.vectors_n, idx.filters_n, qn, fqn, 10, lam)
        gap = float(jnp.mean(oracle_scores - scores))
        assert gap < 0.05, f"lam={lam}: mean score gap {gap}"


def test_multi_probe(data):
    corpus, q, fq = data
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    probes = jnp.stack([fq + 0.1 * i for i in range(3)], axis=1)  # (b, 3, m)
    scores, ids = multi_probe_query(idx, q, probes, 10)
    assert ids.shape == (q.shape[0], 10)
    # no duplicates within each result list
    for row in np.asarray(ids):
        assert len(set(row.tolist())) == len(row)


def test_extend(data):
    corpus, q, fq = data
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=16.0)
    idx = build(jnp.asarray(corpus.vectors[:3000]),
                jnp.asarray(corpus.filters[:3000]), cfg)
    idx2 = extend(idx, jnp.asarray(corpus.vectors[3000:]),
                  jnp.asarray(corpus.filters[3000:]))
    assert idx2.size == 4000
    assert _recall(idx2, q, fq) >= 0.85


def test_scores_sorted_descending(data):
    corpus, q, fq = data
    cfg = FCVIConfig(alpha=1.0, lam=0.5, c=8.0)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    scores, _ = query(idx, q, fq, 10)
    s = np.asarray(scores)
    assert (np.diff(s, axis=1) <= 1e-6).all()


def test_filter_similarity_drives_results(data):
    """Querying with a one-hot category filter must surface rows of that
    category far above its base rate (the paper's core behaviour)."""
    corpus, q, _ = data
    spec = corpus.spec
    cfg = FCVIConfig(alpha=2.0, lam=0.3, c=16.0)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    target = 1
    fq = np.zeros((q.shape[0], spec.m), np.float32)
    fq[:, target] = 1.0
    fq[:, spec.n_categories:] = corpus.filters[:, spec.n_categories:].mean(0)
    _, ids = query(idx, q, jnp.asarray(fq), 10)
    got = corpus.cat_labels[np.asarray(ids).reshape(-1)]
    base_rate = (corpus.cat_labels == target).mean()
    assert (got == target).mean() > max(4 * base_rate, 0.5)
