"""Gather-free re-rank parity: carried rows/scores vs the HBM/psum gather.

The contract: ``EngineConfig.gather_free`` is a pure EXECUTION knob. On a
single device the scan kernels return the winners' re-rank rows straight
from VMEM (no HBM id-gather before rescoring); under ``shard_map`` each
shard gathers its own winners from its LOCAL payload block, rescores them in
place, and the cross-shard merge carries finished scores instead of
psum-gathering rows afterwards. Both variants must return top-k ids and
scores IDENTICAL to the gather-based step — flat, IVF and PQ, kernels on and
off, fp32 and int8 storage, with a live delta buffer, dense and routed.
The collective-free property itself is pinned by
``tests/test_hlo_analysis.py::test_gather_free_step_has_no_all_reduce``.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FCVIConfig, build
from repro.data.synthetic import CorpusSpec, make_corpus, sample_queries
from repro.launch.mesh import make_mesh
from repro.serve.engine import EngineConfig, FCVIEngine

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_in_subprocess(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.fixture(scope="module")
def data():
    spec = CorpusSpec(n=1000, d=64, n_categories=5, n_numeric=3, seed=2)
    corpus = make_corpus(spec)
    q, fq = sample_queries(corpus, 5, seed=3)
    return corpus, np.asarray(q), np.asarray(fq)


def _engine(corpus, backend, use_pallas, gather_free, storage="float32",
            mesh=None, **mesh_kw):
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend=backend, nlist=16,
                     nprobe=4, pq_m=8, pq_ksub=32, pq_coarse=8,
                     use_pallas=use_pallas, storage_dtype=storage)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    ek = EngineConfig(k=5, batch_size=16, compact_threshold=256,
                      gather_free=gather_free)
    return FCVIEngine(idx, ek, mesh=mesh, **mesh_kw)


def _assert_identical(a, b):
    (s0, i0), (s1, i1) = a, b
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


@pytest.mark.parametrize("backend", ["flat", "ivf"])
@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("storage", ["float32", "int8"])
def test_single_device_gather_free_identity(data, backend, use_pallas,
                                            storage):
    """Meshless: the rows-returning scan variants must reproduce the
    gather-based step bit-for-bit, including through a live delta buffer."""
    corpus, q, fq = data
    e0 = _engine(corpus, backend, use_pallas, False, storage)
    e1 = _engine(corpus, backend, use_pallas, True, storage)
    _assert_identical(e0.search(q, fq), e1.search(q, fq))
    r = np.random.default_rng(0)
    nv = r.normal(size=(20, corpus.spec.d)).astype(np.float32)
    nf = corpus.filters[:20].copy()
    e0.insert(nv, nf)
    e1.insert(nv, nf)
    e0._cache.clear()
    e1._cache.clear()
    _assert_identical(e0.search(q, fq), e1.search(q, fq))


@pytest.mark.parametrize("backend", ["flat", "ivf", "pq"])
def test_one_device_mesh_gather_free_identity(data, backend):
    """A 1-device mesh runs the shard_map gather-free step (local gather +
    carried scores); it must match the meshless gather-based engine."""
    corpus, q, fq = data
    mesh = make_mesh((1, 1), ("data", "model"))
    e0 = _engine(corpus, backend, False, False)
    e1 = _engine(corpus, backend, False, True, mesh=mesh)
    _assert_identical(e0.search(q, fq), e1.search(q, fq))


_PRELUDE = """
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import FCVIConfig, build
    from repro.data.synthetic import CorpusSpec, make_corpus, sample_queries
    from repro.launch.mesh import make_mesh
    from repro.serve.engine import EngineConfig, FCVIEngine

    assert len(jax.devices()) == 8
    spec = CorpusSpec(n=1000, d=64, n_categories=5, n_numeric=3, seed=2)
    corpus = make_corpus(spec)
    q, fq = sample_queries(corpus, 5, seed=3)
    q, fq = np.asarray(q), np.asarray(fq)
    mesh = make_mesh((8, 1), ("data", "model"))

    def engine(backend, use_pallas, gather_free, storage="float32",
               use_mesh=True, **kw):
        cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend=backend,
                         nlist=16, nprobe=4, pq_m=8, pq_ksub=32, pq_coarse=8,
                         use_pallas=use_pallas, storage_dtype=storage)
        idx = build(jnp.asarray(corpus.vectors),
                    jnp.asarray(corpus.filters), cfg)
        ek = EngineConfig(k=5, batch_size=16, compact_threshold=256,
                          gather_free=gather_free)
        if use_mesh:
            return FCVIEngine(idx, ek, mesh=mesh, **kw)
        return FCVIEngine(idx, ek, **kw)

    def check(a, b, tag):
        (s0, i0), (s1, i1) = a, b
        assert (np.asarray(i0) == np.asarray(i1)).all(), tag
        assert (np.asarray(s0) == np.asarray(s1)).all(), tag
"""


@pytest.mark.slow
def test_eight_device_gather_free_vs_psum_step():
    """Acceptance: on a forced 8-device mesh the gather-free step (shard-
    local gathers, merge carries scores) is bit-identical to the mask+psum
    step — flat/IVF/PQ, kernels on and off, fp32 and int8, with a delta."""
    run_in_subprocess(_PRELUDE + """
    r = np.random.default_rng(0)
    nv = r.normal(size=(20, spec.d)).astype(np.float32)
    nf = corpus.filters[:20].copy()
    for backend in ("flat", "ivf", "pq"):
        storages = ("float32",) if backend == "pq" else ("float32", "int8")
        for use_pallas in (False, True):
            for storage in storages:
                ref = engine(backend, use_pallas, False, storage,
                             use_mesh=False)
                lg = engine(backend, use_pallas, False, storage)
                gf = engine(backend, use_pallas, True, storage)
                want = ref.search(q, fq)
                tag = (backend, use_pallas, storage)
                check(want, lg.search(q, fq), tag + ("psum",))
                check(want, gf.search(q, fq), tag + ("gather-free",))
                for e in (ref, lg, gf):
                    e.insert(nv, nf)
                    e._cache.clear()
                check(ref.search(q, fq), gf.search(q, fq), tag + ("delta",))
    print("gather-free parity OK")
    """)


@pytest.mark.slow
def test_routed_and_degraded_gather_free():
    """Routing and degraded serving compose with the gather-free step: the
    routed step's extra outputs and the dead-shard skip branches must leave
    results identical to their gather-based counterparts."""
    run_in_subprocess(_PRELUDE + """
    for backend in ("flat", "ivf"):
        pl = "cluster" if backend == "flat" else "contiguous"
        ref = engine(backend, False, False, use_mesh=False)
        gf = engine(backend, False, True, routing="routed", placement=pl)
        check(ref.search(q, fq), gf.search(q, fq), (backend, "routed"))
    for backend in ("flat", "ivf", "pq"):
        lg = engine(backend, False, False)
        gf = engine(backend, False, True)
        for e in (lg, gf):
            e.health.mark_dead([1])
        check(lg.search(q, fq), gf.search(q, fq), (backend, "degraded"))
    print("routed/degraded gather-free OK")
    """)
