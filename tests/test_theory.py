"""Thm 5.3 (cluster separation) and Thm 5.4 (k' sizing) properties."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # graceful skip when not installed
from hypothesis import given, settings, strategies as st

from repro.core import theory
from repro.core.transform import psi_partition


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_thm53_alpha_star_separates(seed):
    """alpha >= alpha* guarantees complete cluster separation whenever the
    feasibility condition (d/m) delta_f > 2 D_v holds."""
    r = np.random.default_rng(seed)
    d, m, k = 32, 4, 3
    centers = 6.0 * r.normal(size=(k, m)).astype(np.float32)
    labels = r.integers(0, k, 60)
    filters = centers[labels]
    vectors = 0.3 * r.normal(size=(60, d)).astype(np.float32)

    # D_v: max intra-cluster vector distance; delta_f: min inter-filter dist
    d_v = 0.0
    for c in range(k):
        idx = np.nonzero(labels == c)[0]
        if len(idx) > 1:
            diff = vectors[idx][:, None] - vectors[idx][None]
            d_v = max(d_v, float(np.sqrt((diff ** 2).sum(-1)).max()))
    cdiff = centers[:, None] - centers[None]
    cd = np.sqrt((cdiff ** 2).sum(-1))
    delta_f = float(cd[cd > 0].min())

    a_star = float(theory.alpha_star(d_v, delta_f, d, m))
    if not np.isfinite(a_star):
        return  # infeasible configuration: theorem makes no claim
    alpha = max(a_star * 1.01, 1.0)
    t = np.asarray(psi_partition(jnp.asarray(vectors), jnp.asarray(filters), alpha))

    intra_max, inter_min = 0.0, np.inf
    dist = np.sqrt(((t[:, None] - t[None]) ** 2).sum(-1))
    same = labels[:, None] == labels[None]
    np.fill_diagonal(same, True)
    intra_max = dist[same].max()
    if (~same).any():
        inter_min = dist[~same].min()
    assert inter_min > intra_max


def test_kprime_monotonic_in_lambda_and_alpha():
    n = 100000
    # k' shrinks as lambda grows (less filter re-ranking headroom needed)
    ks = [theory.k_prime(10, lam, 1.0, n) for lam in (0.1, 0.3, 0.5, 0.9)]
    assert ks == sorted(ks, reverse=True)
    # k' shrinks quadratically as alpha grows (until the k floor binds)
    ka = [theory.k_prime(100, 0.5, a, n) for a in (1.0, 2.0, 4.0)]
    assert ka == sorted(ka, reverse=True)
    assert ka[0] == 4 * ka[1]          # exact 1/alpha^2 scaling
    assert ka[2] == 100                # floor at k once c*k/(lam*a^2) < k


def test_kprime_bounds():
    assert theory.k_prime(10, 0.5, 1.0, 20) <= 20   # capped at N
    assert theory.k_prime(10, 1.0, 10.0, 10**6) >= 10  # never below k


def test_optimal_alpha_clip():
    assert float(theory.optimal_alpha(0.9)) == 1.0       # sqrt(1/9) -> clip
    assert float(theory.optimal_alpha(0.2)) == pytest.approx(2.0, rel=1e-3)


def test_separation_margin_sign():
    # with huge alpha the margin must be positive for separated filters
    margin = theory.separation_margin(d_v=1.0, delta_f=2.0, d=32, m=4,
                                      alpha=10.0)
    assert float(margin) > 0
