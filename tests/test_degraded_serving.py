"""Degraded-mode serving: shard loss, stragglers, corrupt state, poison.

The contract under test (the tentpole acceptance criterion): with shards
marked dead on a forced 8-device host mesh the engine serves EVERY query
without crashing, results are bit-identical to a ground-truth search over
the surviving rows (``faultinject.surviving_reference``), queries the dead
shards could have affected carry a coverage flag, and ``heal()`` restores
full coverage through a bit-identity-validated elastic re-place.

Fast cases (input hardening, the resilience envelope, checkpoint
corruption, health-layer policy) run in-process on a 1-device mesh or no
mesh at all; the multi-shard fault-injection matrix runs in subprocesses
with 8 forced host devices, exactly like tests/test_sharded_engine.py.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FCVIConfig, build
from repro.data.synthetic import CorpusSpec, make_corpus, sample_queries
from repro.launch.mesh import make_mesh
from repro.serve import faultinject as fi
from repro.serve.engine import EngineConfig, FCVIEngine
from repro.serve.health import (BackpressureError, ShardHealth,
                                TransientShardError)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_in_subprocess(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.fixture(scope="module")
def data():
    spec = CorpusSpec(n=800, d=64, n_categories=5, n_numeric=3, seed=5)
    corpus = make_corpus(spec)
    q, fq = sample_queries(corpus, 8, seed=6)
    return corpus, np.asarray(q), np.asarray(fq)


@pytest.fixture(scope="module")
def engine(data):
    corpus, _, _ = data
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend="flat")
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    return FCVIEngine(idx, EngineConfig(k=5, batch_size=8))


# ---------------------------------------------------------------------------
# Satellite: input hardening at the search boundary
# ---------------------------------------------------------------------------

def test_poisoned_inputs_rejected(data, engine):
    corpus, q, fq = data
    d, m = q.shape[1], fq.shape[1]
    for name, bad_q, bad_f in fi.poisoned_inputs(d, m):
        with pytest.raises(ValueError):
            engine.search(bad_q, bad_f)
    # sanity: clean inputs still served
    s, i = engine.search(q, fq)
    assert s.shape == (len(q), 5) and np.isfinite(s).all()


def test_k_exceeding_corpus_rejected(data):
    corpus, q, fq = data
    cfg = FCVIConfig(alpha=1.0, lam=0.6, backend="flat")
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    eng = FCVIEngine(idx, EngineConfig(k=corpus.vectors.shape[0] + 1))
    with pytest.raises(ValueError, match="exceeds corpus"):
        eng.search(q, fq)


# ---------------------------------------------------------------------------
# ShardHealth policy (pure host-side logic — no mesh needed)
# ---------------------------------------------------------------------------

def test_shard_health_straggler_eviction():
    h = ShardHealth(4, straggler_z=1.4, straggler_patience=3)
    evicted = []
    for _ in range(6):
        evicted += h.record_batch([0.01, 0.01, 0.01, 0.2])
    assert evicted == [3]
    assert h.dead_shards() == [3]
    assert h.alive_mask().tolist() == [True, True, True, False]
    assert h.n_alive() == 3 and h.any_dead()


def test_shard_health_recovered_straggler_not_evicted():
    """Recovery before ``straggler_patience`` expires resets the persistence
    count: intermittent slowness never evicts (alpha=1 -> EWMA = latest)."""
    h = ShardHealth(4, alpha=1.0, straggler_z=1.4, straggler_patience=3)
    slow = [0.01, 0.01, 0.01, 0.2]
    fast = [0.01, 0.01, 0.01, 0.01]
    evicted = []
    for times in [slow, slow, slow, fast, slow, slow, fast]:
        evicted += h.record_batch(times)     # never 3 slow checks in a row
    assert evicted == []
    assert h.dead_shards() == []


def test_shard_health_heartbeat_timeout():
    h = ShardHealth(3, timeout_steps=2)
    assert h.check_failures() == []          # fresh layer: nothing silent yet
    for _ in range(4):
        h.record_batch([0.01, 0.01])         # shard 2 never heartbeats
    assert h.check_failures() == [2]
    assert h.dead_shards() == [2]
    h.mark_alive([2])
    assert h.dead_shards() == []


def test_dead_shard_skipped_by_heartbeat_feed():
    h = ShardHealth(2)
    h.mark_dead([1])
    h.record_batch([0.01, 0.01])             # must not resurrect shard 1
    assert h.dead_shards() == [1]


# ---------------------------------------------------------------------------
# Resilience envelope (1-device mesh: the envelope is mesh-size agnostic)
# ---------------------------------------------------------------------------

@pytest.fixture()
def mesh_engine(data):
    corpus, _, _ = data
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend="flat")
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    return FCVIEngine(idx, EngineConfig(k=5, batch_size=8,
                                        retry_backoff_s=0.001),
                      mesh=mesh)


def test_transient_errors_retried_within_budget(data, mesh_engine):
    _, q, fq = data
    mesh_engine.fault_injector = fi.FaultInjector(transient_failures=2)
    s, i = mesh_engine.search(q, fq)
    assert mesh_engine.stats.retries == 2
    assert np.isfinite(s).all()


def test_transient_errors_beyond_budget_propagate(data, mesh_engine):
    _, q, fq = data
    mesh_engine.fault_injector = fi.FaultInjector(transient_failures=10)
    with pytest.raises(TransientShardError):
        mesh_engine.search(q, fq)
    assert mesh_engine.stats.retries == mesh_engine.cfg.max_retries + 1


def test_backpressure_sheds_load(data, mesh_engine):
    _, q, fq = data
    mesh_engine.cfg.queue_budget = 2
    with pytest.raises(BackpressureError):
        mesh_engine.search(q, fq)
    assert mesh_engine.stats.backpressure_drops == len(q)
    mesh_engine.cfg.queue_budget = 0
    mesh_engine.search(q, fq)                # recovers once budget lifted


def test_deadline_misses_counted(data, mesh_engine):
    _, q, fq = data
    mesh_engine.cfg.deadline_s = 1e-9        # nothing beats a nanosecond
    mesh_engine.search(q, fq)
    assert mesh_engine.stats.deadline_misses >= 1


def test_coverage_all_true_while_healthy(data, mesh_engine):
    _, q, fq = data
    mesh_engine.search(q, fq)
    assert mesh_engine.stats.last_coverage.all()
    assert mesh_engine.stats.coverage_rate == 1.0
    assert mesh_engine.stats.degraded_batches == 0


# ---------------------------------------------------------------------------
# Satellite: checkpoint integrity (torn/corrupt state)
# ---------------------------------------------------------------------------

def test_corrupt_newest_step_falls_back(data, engine, tmp_path):
    corpus, q, fq = data
    want = engine.search(q, fq)
    engine.save(str(tmp_path), step=1)
    engine.save(str(tmp_path), step=2)
    fi.corrupt_checkpoint(str(tmp_path), 2, "truncate")
    with pytest.warns(UserWarning, match="skipping corrupt"):
        restored = FCVIEngine.restore(str(tmp_path))
    got = restored.search(q, fq)
    np.testing.assert_array_equal(want[1], got[1])
    np.testing.assert_array_equal(want[0], got[0])


@pytest.mark.parametrize("mode", ["truncate", "flip", "erase_manifest"])
def test_explicit_corrupt_step_raises(data, engine, tmp_path, mode):
    from repro.checkpoint.ckpt import CheckpointCorruptError, load

    engine.save(str(tmp_path), step=1)
    fi.corrupt_checkpoint(str(tmp_path), 1, mode)
    with pytest.raises(CheckpointCorruptError):
        load(str(tmp_path), step=1)


def test_manifest_checksum_mismatch_detected(data, engine, tmp_path):
    """Bit rot that leaves the zip container intact is still caught by the
    manifest crc32s (simulated by tampering with the recorded checksum)."""
    import json

    from repro.checkpoint.ckpt import CheckpointCorruptError, load

    engine.save(str(tmp_path), step=1)
    mpath = tmp_path / "step_00000001" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    key = next(iter(manifest["checksums"]))
    manifest["checksums"][key] ^= 0xFFFF
    mpath.write_text(json.dumps(manifest))
    with pytest.raises(CheckpointCorruptError, match="checksum mismatch"):
        load(str(tmp_path), step=1)


def test_all_steps_corrupt_raises(data, engine, tmp_path):
    from repro.checkpoint.ckpt import CheckpointCorruptError, load

    engine.save(str(tmp_path), step=1)
    fi.corrupt_checkpoint(str(tmp_path), 1, "truncate")
    with pytest.raises(CheckpointCorruptError), pytest.warns(UserWarning):
        load(str(tmp_path))


# ---------------------------------------------------------------------------
# The multi-shard fault-injection matrix (8 forced host devices)
# ---------------------------------------------------------------------------

_SUBPROCESS_PRELUDE = """
    import numpy as np, jax.numpy as jnp, tempfile
    from repro.core import FCVIConfig, build
    from repro.data.synthetic import CorpusSpec, make_corpus, sample_queries
    from repro.launch.mesh import make_mesh
    from repro.serve.engine import EngineConfig, FCVIEngine
    from repro.serve import faultinject as fi

    spec = CorpusSpec(n=1000, d=64, n_categories=5, n_numeric=3, seed=2)
    corpus = make_corpus(spec)
    q, fq = sample_queries(corpus, 16, seed=3)
    q, fq = np.asarray(q), np.asarray(fq)

    def make_engine(backend, use_pallas, placement, routing, n_dev=8):
        cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend=backend,
                         nlist=16, nprobe=4, use_pallas=use_pallas)
        idx = build(jnp.asarray(corpus.vectors),
                    jnp.asarray(corpus.filters), cfg)
        mesh = make_mesh((n_dev, 1), ("data", "model"))
        return FCVIEngine(idx, EngineConfig(k=5, batch_size=16), mesh=mesh,
                          placement=placement, routing=routing)

    def check_degraded(eng, dead):
        s_h, i_h = eng.search(q, fq)             # healthy baseline
        assert eng.stats.last_coverage.all()
        eng.health.mark_dead(dead)
        s_d, i_d = eng.search(q, fq)
        cov = eng.stats.last_coverage.copy()
        ref = fi.surviving_reference(eng)
        s_r, i_r = ref.search(q, fq)
        # 1) bit-identical to the ground truth over surviving rows
        assert np.array_equal(i_d, i_r), "ids differ from surviving ref"
        assert np.array_equal(s_d, s_r), "scores differ from surviving ref"
        # 2) no dead row ever surfaces in degraded results
        mask = fi.surviving_row_mask(eng)
        delta_ok = i_d >= eng.index.size         # delta rows are durable
        assert (mask[np.minimum(i_d, eng.index.size - 1)] | delta_ok).all()
        # 3) coverage soundness: a query whose HEALTHY top-k contains a
        #    dead row must carry the flag (the certificate may over-flag,
        #    never under-flag)
        main = i_h < eng.index.size
        affected = np.zeros(len(q), bool)
        for j in range(len(q)):
            affected[j] = (~mask[i_h[j][main[j]]]).any()
        if affected.any():
            assert (~cov[affected]).all(), "coverage flag missed a query"
        assert eng.stats.degraded_batches > 0
        return int(affected.sum()), int((~cov).sum())
"""


@pytest.mark.slow
@pytest.mark.parametrize("backend,use_pallas", [
    ("flat", False), ("flat", True), ("ivf", False), ("ivf", True)])
def test_dead_shard_bit_identity(backend, use_pallas):
    """1 of 8 shards dead: serve everything, bit-identical to ground truth,
    coverage flags sound — dense and routed, cluster and contiguous."""
    run_in_subprocess(_SUBPROCESS_PRELUDE + f"""
    combos = ([("cluster", "routed"), ("cluster", "dense"),
               ("contiguous", "dense")] if {backend!r} == "flat"
              else [("contiguous", "routed"), ("contiguous", "dense")])
    for placement, routing in combos:
        eng = make_engine({backend!r}, {use_pallas}, placement, routing)
        affected, flagged = check_degraded(eng, [2])
        print(placement, routing, "affected", affected, "flagged", flagged)
    """)


@pytest.mark.slow
def test_two_dead_shards_and_incremental_death():
    """Deaths accumulate without retracing the healthy path; the alive mask
    is a traced argument, so 1 dead and then 2 dead reuse one trace."""
    run_in_subprocess(_SUBPROCESS_PRELUDE + """
    from repro.serve import engine as engine_mod
    eng = make_engine("flat", False, "cluster", "routed")
    eng.search(q, fq)
    eng.health.mark_dead([1])
    eng.search(q, fq)
    traces_after_first_death = engine_mod.trace_count()
    eng.health.mark_dead([6])
    s_d, i_d = eng.search(q, fq)
    assert engine_mod.trace_count() == traces_after_first_death, \\
        "second death must not retrace (alive mask is a traced arg)"
    ref = fi.surviving_reference(eng)
    s_r, i_r = ref.search(q, fq)
    assert np.array_equal(i_d, i_r) and np.array_equal(s_d, s_r)
    """)


@pytest.mark.slow
def test_straggler_eviction_to_degraded_serving():
    """A persistently slow shard is evicted by the health layer mid-serve and
    subsequent results match the surviving reference."""
    run_in_subprocess(_SUBPROCESS_PRELUDE + """
    eng = make_engine("flat", False, "cluster", "routed")
    eng.cfg.straggler_z = 2.0
    from repro.serve.health import ShardHealth
    eng.health = ShardHealth(8, straggler_z=2.0)
    eng.fault_injector = fi.FaultInjector(slow_shards={5: 10.0})
    rng = np.random.default_rng(1)
    for _ in range(8):
        qq = q + rng.normal(size=q.shape).astype(np.float32) * 0.01
        eng.search(qq, fq)
    assert eng.health.dead_shards() == [5]
    assert eng.stats.straggler_evictions == 1
    s_d, i_d = eng.search(q, fq)
    ref = fi.surviving_reference(eng)
    s_r, i_r = ref.search(q, fq)
    assert np.array_equal(i_d, i_r) and np.array_equal(s_d, s_r)
    """)


@pytest.mark.slow
def test_heal_restores_full_coverage():
    """The acceptance criterion end to end: kill a shard, serve degraded,
    heal onto the 7 survivors, and full-coverage results return —
    bit-identical to a meshless engine over the full corpus."""
    run_in_subprocess(_SUBPROCESS_PRELUDE + """
    for placement, routing in [("cluster", "routed"), ("contiguous", "dense")]:
        eng = make_engine("flat", False, placement, routing)
        eng.health.mark_dead([3])
        eng.search(q, fq)
        assert not eng.stats.last_coverage.all()
        with tempfile.TemporaryDirectory() as d:
            assert eng.heal(d, q, fq) is True
        assert eng._sharded.n_shards == 7
        assert eng.stats.heals == 1
        s, i = eng.search(q, fq)
        assert eng.stats.last_coverage.all()
        cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend="flat")
        idx = build(jnp.asarray(corpus.vectors),
                    jnp.asarray(corpus.filters), cfg)
        ref = FCVIEngine(idx, EngineConfig(k=5, batch_size=16))
        s_r, i_r = ref.search(q, fq)
        assert np.array_equal(i, i_r) and np.array_equal(s, s_r)
        print(placement, routing, "healed")
    """)


@pytest.mark.slow
def test_heal_background_thread():
    run_in_subprocess(_SUBPROCESS_PRELUDE + """
    eng = make_engine("ivf", False, "contiguous", "dense")
    eng.health.mark_dead([0])
    eng.search(q, fq)
    with tempfile.TemporaryDirectory() as d:
        t = eng.heal(d, q, fq, background=True)
        t.join(timeout=600)
        assert not t.is_alive()
    assert eng.stats.heals == 1 and eng._sharded.n_shards == 7
    eng.search(q, fq)
    assert eng.stats.last_coverage.all()
    """)


@pytest.mark.slow
def test_degraded_with_delta_buffer():
    """Delta rows are host-durable: they keep serving (and merging) while a
    shard is dead, and the surviving reference carries the same delta."""
    run_in_subprocess(_SUBPROCESS_PRELUDE + """
    eng = make_engine("flat", False, "cluster", "dense")
    rng = np.random.default_rng(7)
    nv = rng.normal(size=(20, corpus.spec.d)).astype(np.float32)
    nf = corpus.filters[:20].copy()
    eng.insert(nv, nf)
    eng.health.mark_dead([4])
    s_d, i_d = eng.search(q, fq)
    ref = fi.surviving_reference(eng)
    assert ref.delta_size() == 20
    s_r, i_r = ref.search(q, fq)
    assert np.array_equal(i_d, i_r) and np.array_equal(s_d, s_r)
    """)
