"""Distributed-index invariants: top-k merge algebra, filter-centric layout."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # graceful skip when not installed
from hypothesis import given, settings, strategies as st

from repro.core.clustering import kmeans
from repro.index.distributed import cluster_sharded_layout
from repro.index.flat import merge_topk


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 16), st.integers(1, 32), st.integers(1, 32),
       st.integers(0, 2**31 - 1))
def test_merge_topk_equals_global_topk(k, na, nb, seed):
    """merge(topk(A), topk(B)) == topk(A ∪ B) — the tree-merge soundness
    property the multi-pod search relies on."""
    r = np.random.default_rng(seed)
    k = min(k, na + nb)
    va = jnp.asarray(r.normal(size=(3, na)).astype(np.float32))
    vb = jnp.asarray(r.normal(size=(3, nb)).astype(np.float32))
    ia = jnp.broadcast_to(jnp.arange(na), (3, na))
    ib = jnp.broadcast_to(jnp.arange(nb) + na, (3, nb))
    mv, mi = merge_topk(va, ia, vb, ib, k)
    allv = jnp.concatenate([va, vb], axis=1)
    ref_v, ref_pos = jax.lax.top_k(allv, k)
    np.testing.assert_allclose(np.asarray(mv), np.asarray(ref_v), rtol=1e-6)


def test_merge_topk_associativity():
    r = np.random.default_rng(1)
    parts = [jnp.asarray(r.normal(size=(2, 8)).astype(np.float32))
             for _ in range(3)]
    ids = [jnp.broadcast_to(jnp.arange(8) + 8 * i, (2, 8)) for i in range(3)]
    k = 5
    ab_v, ab_i = merge_topk(parts[0], ids[0], parts[1], ids[1], k)
    left_v, left_i = merge_topk(ab_v, ab_i, parts[2], ids[2], k)
    bc_v, bc_i = merge_topk(parts[1], ids[1], parts[2], ids[2], k)
    right_v, right_i = merge_topk(parts[0], ids[0], bc_v, bc_i, k)
    np.testing.assert_allclose(np.asarray(left_v), np.asarray(right_v),
                               rtol=1e-6)


def test_cluster_sharded_layout_is_permutation():
    r = np.random.default_rng(2)
    v = jnp.asarray(r.normal(size=(1024, 16)).astype(np.float32))
    centers, _ = kmeans(jax.random.PRNGKey(0), v, 8, iters=5)
    perm, shard_of_cluster = cluster_sharded_layout(v, centers, n_shards=4)
    p = np.asarray(perm)
    assert sorted(p.tolist()) == list(range(1024))       # true permutation
    assert shard_of_cluster.shape == (8,)
    assert (np.asarray(shard_of_cluster) < 4).all()


def test_cluster_layout_balances_shards():
    r = np.random.default_rng(3)
    v = jnp.asarray(r.normal(size=(4096, 8)).astype(np.float32))
    centers, _ = kmeans(jax.random.PRNGKey(1), v, 16, iters=5)
    perm, _ = cluster_sharded_layout(v, centers, n_shards=8)
    # contiguous equal shards by construction
    assert perm.shape[0] == 4096


def test_merge_topk_duplicate_ids_across_sets():
    """Shard/delta candidate sets may carry the same id (e.g. a row probed on
    two paths): both occurrences compete and the best score wins the front
    slot — merge_topk is rank-only, dedup is the caller's contract."""
    va = jnp.asarray([[3.0, 1.0]])
    ia = jnp.asarray([[7, 9]])
    vb = jnp.asarray([[2.5, 0.5]])
    ib = jnp.asarray([[7, 11]])
    mv, mi = merge_topk(va, ia, vb, ib, 3)
    np.testing.assert_allclose(np.asarray(mv), [[3.0, 2.5, 1.0]])
    np.testing.assert_array_equal(np.asarray(mi), [[7, 7, 9]])


def test_merge_topk_k_larger_than_total_candidates():
    """k beyond the pooled candidate count pads with -inf scores / id 0 (the
    backend convention for unfillable rows) instead of erroring — the shape
    a shard-merge stage needs when small shards under-fill their sets."""
    va = jnp.asarray([[1.0, 0.0]])
    ia = jnp.asarray([[4, 5]])
    vb = jnp.asarray([[0.5]])
    ib = jnp.asarray([[6]])
    mv, mi = merge_topk(va, ia, vb, ib, 6)
    assert mv.shape == (1, 6) and mi.shape == (1, 6)
    np.testing.assert_allclose(np.asarray(mv)[0, :3], [1.0, 0.5, 0.0])
    assert np.isneginf(np.asarray(mv)[0, 3:]).all()
    np.testing.assert_array_equal(np.asarray(mi)[0, 3:], 0)


def test_merge_topk_all_padding_shard():
    """An all-padding shard (every score -inf) must never displace real
    candidates, and an all-padding merge stays all-padding."""
    pad_v = jnp.full((2, 4), -jnp.inf)
    pad_i = jnp.zeros((2, 4), jnp.int32)
    real_v = jnp.asarray([[2.0, 1.0, 0.5, 0.1], [9.0, 8.0, 7.0, 6.0]])
    real_i = jnp.arange(8, dtype=jnp.int32).reshape(2, 4)
    mv, mi = merge_topk(real_v, real_i, pad_v, pad_i, 4)
    np.testing.assert_allclose(np.asarray(mv), np.asarray(real_v))
    np.testing.assert_array_equal(np.asarray(mi), np.asarray(real_i))
    mv2, _ = merge_topk(pad_v, pad_i, pad_v, pad_i, 4)
    assert np.isneginf(np.asarray(mv2)).all()


def test_balanced_list_layout_packs_within_capacity():
    """IVF list placement: every list lands on exactly one shard, shard slot
    capacity is respected, and row loads stay near-balanced."""
    from repro.index.slab import balanced_list_layout

    r = np.random.default_rng(5)
    sizes = r.integers(1, 200, size=37)
    ns, cap = 8, -(-37 // 8)
    shard_of, slot_in = balanced_list_layout(sizes, ns, cap)
    assert shard_of.shape == (37,) and (shard_of < ns).all()
    for s in range(ns):
        mine = shard_of == s
        assert mine.sum() <= cap
        # slots within a shard are distinct
        assert len(set(slot_in[mine].tolist())) == mine.sum()
    loads = np.asarray([sizes[shard_of == s].sum() for s in range(ns)])
    assert loads.max() - loads.min() <= sizes.max()
