"""Distributed-index invariants: top-k merge algebra, filter-centric layout."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # graceful skip when not installed
from hypothesis import given, settings, strategies as st

from repro.core.clustering import kmeans
from repro.index.distributed import cluster_sharded_layout
from repro.index.flat import merge_topk


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 16), st.integers(1, 32), st.integers(1, 32),
       st.integers(0, 2**31 - 1))
def test_merge_topk_equals_global_topk(k, na, nb, seed):
    """merge(topk(A), topk(B)) == topk(A ∪ B) — the tree-merge soundness
    property the multi-pod search relies on."""
    r = np.random.default_rng(seed)
    k = min(k, na + nb)
    va = jnp.asarray(r.normal(size=(3, na)).astype(np.float32))
    vb = jnp.asarray(r.normal(size=(3, nb)).astype(np.float32))
    ia = jnp.broadcast_to(jnp.arange(na), (3, na))
    ib = jnp.broadcast_to(jnp.arange(nb) + na, (3, nb))
    mv, mi = merge_topk(va, ia, vb, ib, k)
    allv = jnp.concatenate([va, vb], axis=1)
    ref_v, ref_pos = jax.lax.top_k(allv, k)
    np.testing.assert_allclose(np.asarray(mv), np.asarray(ref_v), rtol=1e-6)


def test_merge_topk_associativity():
    r = np.random.default_rng(1)
    parts = [jnp.asarray(r.normal(size=(2, 8)).astype(np.float32))
             for _ in range(3)]
    ids = [jnp.broadcast_to(jnp.arange(8) + 8 * i, (2, 8)) for i in range(3)]
    k = 5
    ab_v, ab_i = merge_topk(parts[0], ids[0], parts[1], ids[1], k)
    left_v, left_i = merge_topk(ab_v, ab_i, parts[2], ids[2], k)
    bc_v, bc_i = merge_topk(parts[1], ids[1], parts[2], ids[2], k)
    right_v, right_i = merge_topk(parts[0], ids[0], bc_v, bc_i, k)
    np.testing.assert_allclose(np.asarray(left_v), np.asarray(right_v),
                               rtol=1e-6)


def test_cluster_sharded_layout_is_permutation():
    r = np.random.default_rng(2)
    v = jnp.asarray(r.normal(size=(1024, 16)).astype(np.float32))
    centers, _ = kmeans(jax.random.PRNGKey(0), v, 8, iters=5)
    perm, shard_of_cluster = cluster_sharded_layout(v, centers, n_shards=4)
    p = np.asarray(perm)
    assert sorted(p.tolist()) == list(range(1024))       # true permutation
    assert shard_of_cluster.shape == (8,)
    assert (np.asarray(shard_of_cluster) < 4).all()


def test_cluster_layout_balances_shards():
    r = np.random.default_rng(3)
    v = jnp.asarray(r.normal(size=(4096, 8)).astype(np.float32))
    centers, _ = kmeans(jax.random.PRNGKey(1), v, 16, iters=5)
    perm, _ = cluster_sharded_layout(v, centers, n_shards=8)
    # contiguous equal shards by construction
    assert perm.shape[0] == 4096
