"""Pre/post/hybrid baselines vs the binary-predicate oracle (paper §2.2)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BoxPredicate, post_filter_search, pre_filter_search,
                        build_hybrid, hybrid_search, ground_truth_filtered,
                        recall_at_k)
from repro.data.synthetic import CorpusSpec, make_corpus, sample_queries
from repro.index import flat as flat_mod


@pytest.fixture(scope="module")
def setup():
    spec = CorpusSpec(n=4000, d=48, n_categories=4, n_numeric=2, seed=3)
    corpus = make_corpus(spec)
    q, _ = sample_queries(corpus, 8, seed=4)
    v = jnp.asarray(corpus.vectors)
    f = jnp.asarray(corpus.filters)
    m = spec.m
    # moderate-selectivity numeric range predicate on the last attribute
    lo = np.full(m, -np.inf, np.float32)
    hi = np.full(m, np.inf, np.float32)
    lo[-1], hi[-1] = 0.2, 0.6
    pred = BoxPredicate(low=jnp.asarray(lo), high=jnp.asarray(hi))
    sel = float(np.asarray(pred.mask(f)).mean())
    assert 0.1 < sel < 0.7, f"bad selectivity {sel}"
    return corpus, v, f, jnp.asarray(q), pred


def test_pre_filter_is_exact(setup):
    corpus, v, f, q, pred = setup
    idx = flat_mod.build(v)
    _, ids = pre_filter_search(idx, f, q, pred, 10)
    _, ref = ground_truth_filtered(v, f, q, pred, 10)
    assert float(recall_at_k(ids, ref)) > 0.999


def test_post_filter_recall_with_oversampling(setup):
    corpus, v, f, q, pred = setup
    idx = flat_mod.build(v)
    _, ids = post_filter_search(idx, f, q, pred, 10, oversample=40)
    _, ref = ground_truth_filtered(v, f, q, pred, 10)
    assert float(recall_at_k(ids, ref)) > 0.9


def test_post_filter_degrades_with_low_oversampling(setup):
    """The paper's core criticism of post-filtering: selective predicates
    starve the candidate set."""
    corpus, v, f, q, pred = setup
    idx = flat_mod.build(v)
    _, ids_small = post_filter_search(idx, f, q, pred, 10, oversample=2)
    _, ids_big = post_filter_search(idx, f, q, pred, 10, oversample=40)
    _, ref = ground_truth_filtered(v, f, q, pred, 10)
    assert recall_at_k(ids_small, ref) <= recall_at_k(ids_big, ref)


def test_post_filter_results_satisfy_predicate(setup):
    corpus, v, f, q, pred = setup
    idx = flat_mod.build(v)
    vals, ids = post_filter_search(idx, f, q, pred, 10, oversample=40)
    got = np.asarray(pred.mask(f[ids]))
    valid = np.asarray(vals) > -np.inf
    assert got[valid].all()


def test_hybrid_routes_and_recalls(setup):
    corpus, v, f, q, pred = setup
    h = build_hybrid(v, f, key_dim=f.shape[1] - 1, n_segments=16)
    _, ids = hybrid_search(h, q, pred, 10)
    _, ref = ground_truth_filtered(v, f, q, pred, 10)
    assert float(recall_at_k(ids, ref)) > 0.85


def test_hybrid_pre_path_on_narrow_range(setup):
    corpus, v, f, q, _ = setup
    m = f.shape[1]
    lo = np.full(m, -np.inf, np.float32)
    hi = np.full(m, np.inf, np.float32)
    lo[-1], hi[-1] = 0.30, 0.34   # very narrow -> segment pre-filter path
    pred = BoxPredicate(low=jnp.asarray(lo), high=jnp.asarray(hi))
    h = build_hybrid(v, f, key_dim=m - 1, n_segments=16)
    vals, ids = hybrid_search(h, q, pred, 10, pre_threshold=0.25)
    _, ref = ground_truth_filtered(v, f, q, pred, 10)
    assert float(recall_at_k(ids, ref)) > 0.95


def test_predicate_probes_span_box():
    lo = jnp.asarray([0.0, -1.0])
    hi = jnp.asarray([1.0, 1.0])
    pred = BoxPredicate(low=lo, high=hi)
    pr = np.asarray(pred.probes(5))
    assert pr.shape == (5, 2)
    np.testing.assert_allclose(pr[0], [0.0, -1.0])
    np.testing.assert_allclose(pr[-1], [1.0, 1.0])
