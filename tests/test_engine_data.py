"""Serving engine behaviour + data pipeline determinism + shift protocols."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FCVIConfig, build, BoxPredicate
from repro.data.synthetic import (CorpusSpec, make_corpus, sample_queries,
                                  shift_filter_distribution,
                                  shift_vector_distribution,
                                  shifted_query_pattern)
from repro.data.tokens import MarkovTokens, TokenSpec
from repro.serve.engine import EngineConfig, FCVIEngine


@pytest.fixture(scope="module")
def engine():
    spec = CorpusSpec(n=3000, d=32, n_categories=6, n_numeric=2, seed=5)
    corpus = make_corpus(spec)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters),
                FCVIConfig(alpha=1.0, lam=0.6, c=8.0))
    return corpus, FCVIEngine(idx, EngineConfig(k=5, batch_size=16,
                                                compact_threshold=64))


def test_engine_search_and_cache(engine):
    corpus, eng = engine
    q, fq = sample_queries(corpus, 8, seed=6)
    s1, i1 = eng.search(q, fq)
    assert s1.shape == (8, 5)
    hits_before = eng.stats.cache_hits
    s2, i2 = eng.search(q, fq)      # identical queries -> cache
    assert eng.stats.cache_hits == hits_before + 8
    np.testing.assert_array_equal(i1, i2)


def test_engine_insert_delta_and_compaction(engine):
    corpus, eng = engine
    spec = corpus.spec
    r = np.random.default_rng(11)
    # insert a batch small enough to stay in the delta buffer
    nv = r.normal(size=(8, spec.d)).astype(np.float32)
    nf = corpus.filters[:8].copy()
    base_size = eng.index.size
    eng.insert(nv, nf)
    assert eng.delta_size() == 8
    # a query identical to an inserted vector must retrieve it from the delta
    s, ids = eng.search(nv[:2], nf[:2])
    assert (ids >= base_size).any()
    # exceeding the threshold compacts into the main index
    big_v = r.normal(size=(64, spec.d)).astype(np.float32)
    eng.insert(big_v, corpus.filters[:64].copy())
    assert eng.delta_size() == 0
    assert eng.index.size == base_size + 8 + 64
    assert eng.stats.compactions >= 1


def test_engine_batch_step_does_not_retrace():
    """The jitted engine step must trace once per (padded shape, delta
    config); steady-state batches of the same shape may not recompile."""
    from repro.serve import engine as engine_mod

    spec = CorpusSpec(n=1500, d=32, n_categories=6, n_numeric=2, seed=8)
    corpus = make_corpus(spec)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters),
                FCVIConfig(alpha=1.0, lam=0.6, c=8.0))
    # escalate_margin=1e9 forces the escalation stage every batch, so BOTH
    # traces (stage 1 + stage 2) happen at warmup and any later compile is a
    # genuine retracing regression
    eng = FCVIEngine(idx, EngineConfig(k=5, batch_size=16,
                                       compact_threshold=512,
                                       escalate_margin=1e9))
    r = np.random.default_rng(4)
    eng.insert(r.normal(size=(16, spec.d)).astype(np.float32),
               corpus.filters[:16].copy())
    q, fq = sample_queries(corpus, 16, seed=9)
    eng.search(q, fq)                      # warmup: traces both stages
    warm = engine_mod.trace_count()
    for seed in (10, 11, 12):
        q, fq = sample_queries(corpus, 16, seed=seed)
        eng._cache.clear()
        eng.search(q, fq)
    assert engine_mod.trace_count() == warm, (
        "engine batch step retraced on a steady-state batch")


def test_engine_config_default_not_shared():
    """Regression: the default EngineConfig must be constructed per engine,
    not shared mutable state across engines."""
    spec = CorpusSpec(n=200, d=16, n_categories=6, n_numeric=2, seed=3)
    corpus = make_corpus(spec)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters),
                FCVIConfig(alpha=1.0, lam=0.6))
    a, b = FCVIEngine(idx), FCVIEngine(idx)
    assert a.cfg is not b.cfg
    a.cfg.k = 3
    assert b.cfg.k != 3


def test_engine_predicate_multiprobe(engine):
    corpus, eng = engine
    spec = corpus.spec
    q, _ = sample_queries(corpus, 4, seed=7)
    lo = np.full(spec.m, -np.inf, np.float32)
    hi = np.full(spec.m, np.inf, np.float32)
    lo[-1], hi[-1] = 0.2, 0.8
    pred = BoxPredicate(low=jnp.asarray(lo), high=jnp.asarray(hi))
    scores, ids = eng.search_predicate(q, pred)
    assert ids.shape == (4, 5)


def test_markov_tokens_deterministic():
    spec = TokenSpec(vocab_size=64, batch=4, seq_len=32, seed=3)
    a = next(iter(MarkovTokens(spec)))["tokens"]
    b = next(iter(MarkovTokens(spec)))["tokens"]
    np.testing.assert_array_equal(a, b)
    # different hosts draw different data
    spec2 = TokenSpec(vocab_size=64, batch=4, seq_len=32, seed=3, host_id=1)
    c = next(iter(MarkovTokens(spec2)))["tokens"]
    assert not np.array_equal(a, c)


def test_markov_tokens_learnable_structure():
    """Transitions are concentrated: next-token entropy << uniform."""
    spec = TokenSpec(vocab_size=64, batch=64, seq_len=64, seed=0, branching=4)
    toks = next(iter(MarkovTokens(spec)))["tokens"]
    pairs = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            pairs.setdefault(int(a), set()).add(int(b))
    avg_succ = np.mean([len(v) for v in pairs.values()])
    assert avg_succ <= 4.5  # branching-limited, not uniform-64


def test_shift_protocols_change_distributions():
    spec = CorpusSpec(n=2000, d=16, n_categories=6, n_numeric=2, seed=9)
    corpus = make_corpus(spec)
    shifted_f = shift_filter_distribution(corpus)
    # category histogram must actually change
    h0 = np.bincount(corpus.cat_labels, minlength=6)
    h1 = np.bincount(shifted_f.cat_labels, minlength=6)
    assert (h0 != h1).any()
    assert not np.array_equal(shifted_f.filters, corpus.filters)

    shifted_v = shift_vector_distribution(corpus, frac_new=0.25)
    assert shifted_v.vectors.shape == corpus.vectors.shape
    assert (shifted_v.vec_labels >= spec.n_vec_clusters).sum() > 0

    q, fq = shifted_query_pattern(corpus, 32)
    assert q.shape == (32, spec.d) and fq.shape == (32, spec.m)
