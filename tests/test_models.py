"""Per-arch smoke tests: reduced config, one forward/train step, serving
consistency. (Deliverable f: REDUCED same-family configs on CPU.)"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced
from repro.models import model as M

ARCHS = list_archs()
RNG = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    batch = {"tokens": jax.random.randint(RNG, (b, s), 0, cfg.vocab_size)}
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(RNG, (b, 16, cfg.d_model))
    if cfg.frontend == "vision_stub":
        batch["patches"] = jax.random.normal(RNG, (b, cfg.n_prefix, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced(get_config(arch))
    params = M.init_params(RNG, cfg)
    batch = _batch(cfg)
    logits = M.forward(params, cfg, batch)
    prefix = cfg.n_prefix if cfg.frontend == "vision_stub" else 0
    assert logits.shape == (2, 32 + prefix, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch):
    cfg = reduced(get_config(arch))
    params = M.init_params(RNG, cfg)
    batch = _batch(cfg)
    loss, metrics = M.lm_loss(params, cfg, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: M.lm_loss(p, cfg, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))) for g in flat)
    gnorm = float(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in flat) ** 0.5)
    assert 0 < gnorm < 1e3


@pytest.mark.parametrize("arch", ["gemma3-1b", "recurrentgemma-2b",
                                  "xlstm-125m", "whisper-large-v3",
                                  "dbrx-132b"])
def test_serving_consistency(arch):
    """prefill + decode must reproduce teacher-forced forward logits."""
    cfg = reduced(get_config(arch))
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    params = M.init_params(RNG, cfg)
    b, s = 2, 24
    tokens = jax.random.randint(RNG, (b, s), 0, cfg.vocab_size)
    batch = _batch(cfg, b, s)
    batch["tokens"] = tokens
    full = M.forward(params, cfg, batch)
    prefix = cfg.n_prefix if cfg.frontend == "vision_stub" else 0
    sp = s - 4
    pb = dict(batch)
    pb["tokens"] = tokens[:, :sp]
    logits_p, cache = M.prefill(params, cfg, pb, max_len=64)
    errs = [float(jnp.max(jnp.abs(logits_p[:, 0] - full[:, prefix + sp - 1])))]
    for t in range(sp, s - 1):
        lg, cache = M.decode_step(params, cfg, tokens[:, t:t + 1], cache)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, prefix + t]))))
    assert max(errs) < 0.15, f"decode drift {max(errs)}"


def test_pattern_cycling():
    cfg = get_config("gemma3-1b")
    kinds = cfg.layer_kinds()
    assert len(kinds) == 26
    assert kinds[:6] == ["local"] * 5 + ["attn"]
    assert cfg.rest_kinds == ("local", "local")
    cfg2 = get_config("recurrentgemma-2b")
    assert cfg2.layer_kinds()[:3] == ["rec", "rec", "local"]


def test_vocab_padding():
    cfg = get_config("whisper-large-v3")
    assert cfg.padded_vocab % 256 == 0
    assert cfg.padded_vocab >= cfg.vocab_size
    cfg2 = get_config("mistral-nemo-12b")
    assert cfg2.padded_vocab == cfg2.vocab_size  # already divisible


def test_param_counts_full_configs():
    """Full (non-reduced) param counts are in the right ballpark — catches
    config transcription errors without allocating (eval_shape only)."""
    import functools
    expected = {
        "gemma3-1b": (0.7e9, 1.6e9),
        "recurrentgemma-2b": (2.0e9, 3.3e9),
        "starcoder2-7b": (6.0e9, 8.5e9),
        "mistral-nemo-12b": (11e9, 14e9),
        "gemma2-27b": (24e9, 30e9),
        "dbrx-132b": (110e9, 140e9),
        "xlstm-125m": (0.05e9, 0.2e9),  # d_ff=0 per assignment: no MLP stack
        "granite-moe-3b-a800m": (2.5e9, 4.0e9),
        "internvl2-26b": (18e9, 26e9),
        "whisper-large-v3": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = get_config(arch)
        sds = jax.eval_shape(functools.partial(M.init_params, cfg=cfg), RNG)
        n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(sds))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of range"
