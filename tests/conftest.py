import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
    config.addinivalue_line(
        "markers",
        "property: randomized property-based differential test "
        "(hypothesis-driven when installed, fixed-seed fallback otherwise)")
