import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
