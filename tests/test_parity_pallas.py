"""use_pallas=True vs False must be a pure performance knob: identical results.

Covers the full query path (fcvi.query) on all three backends, the batched
IVF kernel, multi-probe, the serving engine with a live delta buffer, and
non-divisible batch/corpus shapes (n=1000 is not a multiple of the kernel's
128-row blocks; b=5 is not a multiple of the 64-query / 8-rescore blocks).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FCVIConfig, build, query, multi_probe_query
from repro.core.transform import fit_transform
from repro.data.synthetic import CorpusSpec, make_corpus, sample_queries
from repro.index import flat as flat_mod
from repro.index import ivf as ivf_mod
from repro.index import pq as pq_mod
from repro.serve.engine import EngineConfig, FCVIEngine


@pytest.fixture(scope="module")
def data():
    spec = CorpusSpec(n=1000, d=64, n_categories=5, n_numeric=3, seed=2)
    corpus = make_corpus(spec)
    q, fq = sample_queries(corpus, 5, seed=3)
    return corpus, jnp.asarray(q), jnp.asarray(fq)


def _with_pallas(index):
    return dataclasses.replace(
        index, config=dataclasses.replace(index.config, use_pallas=True))


def _assert_same(a, b, atol=1e-4):
    (s0, i0), (s1, i1) = a, b
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               rtol=1e-4, atol=atol)
    assert (np.asarray(i0) == np.asarray(i1)).all()


@pytest.mark.parametrize("backend", ["flat", "ivf", "pq"])
def test_query_parity(data, backend):
    corpus, q, fq = data
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend=backend,
                     nlist=16, nprobe=16, pq_m=8, pq_ksub=32, pq_coarse=8)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    _assert_same(query(idx, q, fq, 7), query(_with_pallas(idx), q, fq, 7))


def test_multi_probe_parity(data):
    corpus, q, fq = data
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    probes = jnp.stack([fq + 0.1 * i for i in range(3)], axis=1)
    _assert_same(multi_probe_query(idx, q, probes, 7),
                 multi_probe_query(_with_pallas(idx), q, probes, 7))


@pytest.mark.parametrize("n,b,k", [(1000, 5, 10), (256, 3, 300)])
def test_flat_backend_parity(n, b, k):
    """Direct backend parity, incl. k > n clamping and padded shapes."""
    r = np.random.default_rng(n)
    x = jnp.asarray(r.normal(size=(n, 32)).astype(np.float32))
    q = jnp.asarray(r.normal(size=(b, 32)).astype(np.float32))
    idx = flat_mod.build(x)
    _assert_same(idx.search(q, k), idx.search(q, k, use_pallas=True))


def test_ivf_backend_parity_including_unfilled_rows():
    """nprobe=1 with k > list size: -inf padding rows must agree too."""
    r = np.random.default_rng(7)
    x = jnp.asarray(r.normal(size=(500, 32)).astype(np.float32))
    q = jnp.asarray(r.normal(size=(4, 32)).astype(np.float32))
    idx = ivf_mod.build(x, nlist=16)
    for k, nprobe in ((10, 4), (200, 1)):
        v0, i0 = ivf_mod.search(idx, q, k, nprobe=nprobe)
        v1, i1 = ivf_mod.search(idx, q, k, nprobe=nprobe, use_pallas=True)
        v0, v1 = np.asarray(v0), np.asarray(v1)
        assert (np.isneginf(v0) == np.isneginf(v1)).all()
        fin = np.isfinite(v0)
        np.testing.assert_allclose(v0[fin], v1[fin], rtol=1e-4, atol=1e-4)
        assert (np.asarray(i0)[fin] == np.asarray(i1)[fin]).all()


def test_pq_backend_parity():
    r = np.random.default_rng(11)
    x = jnp.asarray(r.normal(size=(700, 32)).astype(np.float32))
    q = jnp.asarray(r.normal(size=(3, 32)).astype(np.float32))
    idx = pq_mod.build(x, m_subspaces=4, ksub=32, ncoarse=8)
    _assert_same(idx.search(q, 10), idx.search(q, 10, use_pallas=True))


@pytest.mark.parametrize("mode,n,d,m", [
    ("partition", 300, 64, 4),   # 300 rows: pads to the kernel block multiple
    ("partition", 37, 48, 3),
    ("cluster", 200, 32, 4),
    ("embedding", 128, 64, 8),
])
def test_transform_apply_parity(mode, n, d, m):
    """Transform.apply/apply_normalized kernel dispatch vs the jnp path."""
    r = np.random.default_rng(n + m)
    v = jnp.asarray(r.normal(size=(n, d)).astype(np.float32))
    f = jnp.asarray(r.normal(size=(n, m)).astype(np.float32))
    kw = dict(n_clusters=4) if mode == "cluster" else {}
    tfm = fit_transform(v, f, 1.5, mode, **kw)
    np.testing.assert_allclose(
        np.asarray(tfm.apply(v, f)),
        np.asarray(tfm.apply(v, f, use_pallas=True)), rtol=2e-5, atol=2e-5)
    vn, fn = tfm.normalize(v, f)
    np.testing.assert_allclose(
        np.asarray(tfm.apply_normalized(vn, fn)),
        np.asarray(tfm.apply_normalized(vn, fn, use_pallas=True)),
        rtol=2e-5, atol=2e-5)


def test_transform_apply_parity_non_divisible_dims():
    """embedding mode with d % m != 0 (explicit proj) must dispatch too."""
    r = np.random.default_rng(50)
    v = jnp.asarray(r.normal(size=(10, 50)).astype(np.float32))
    f = jnp.asarray(r.normal(size=(10, 3)).astype(np.float32))
    proj = jnp.asarray(r.normal(size=(50, 3)).astype(np.float32))
    tfm = fit_transform(v, f, 1.0, "embedding", proj=proj)
    np.testing.assert_allclose(
        np.asarray(tfm.apply(v, f)),
        np.asarray(tfm.apply(v, f, use_pallas=True)), rtol=2e-5, atol=2e-5)
    # leading batch axes flatten through the kernel and reshape back
    v3, f3 = v.reshape(5, 2, 50), f.reshape(5, 2, 3)
    out = tfm.apply(v3, f3, use_pallas=True)
    assert out.shape == (5, 2, 50)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(tfm.apply(v3, f3)),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("backend", ["flat", "ivf"])
def test_bf16_storage_matches_fp32_within_refine_guarantee(data, backend):
    """bf16 corpus storage: candidate generation reads half-width rows, but
    re-ranking runs on the fp32 normalized originals, so the returned top-k
    must agree with the fp32-storage index (the exact-refine guarantee)."""
    corpus, q, fq = data
    kw = dict(alpha=1.0, lam=0.6, c=8.0, backend=backend, nlist=16, nprobe=16)
    i32 = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters),
                FCVIConfig(**kw))
    i16 = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters),
                FCVIConfig(storage_dtype="bfloat16", **kw))
    assert i16.backend.vectors.dtype == jnp.bfloat16
    s32, id32 = query(i32, q, fq, 10)
    s16, id16 = query(i16, q, fq, 10)
    id32, id16 = np.asarray(id32), np.asarray(id16)
    overlap = np.mean([
        len(set(id32[i]) & set(id16[i])) / id32.shape[1]
        for i in range(id32.shape[0])])
    assert overlap >= 0.9
    # where the same candidate surfaced, its combined score is computed on
    # the fp32 normalized originals either way -> must match tightly
    same = id32 == id16
    np.testing.assert_allclose(np.asarray(s32)[same], np.asarray(s16)[same],
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["flat", "ivf"])
def test_bf16_storage_pallas_parity(data, backend):
    """kernels on vs off must still be a pure perf knob under bf16 storage."""
    corpus, q, fq = data
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend=backend, nlist=16,
                     nprobe=16, storage_dtype="bfloat16")
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    _assert_same(query(idx, q, fq, 7), query(_with_pallas(idx), q, fq, 7))


def test_engine_parity_with_delta(data):
    """Full serving path incl. the batched delta merge, kernels on vs off."""
    corpus, q, fq = data
    spec = corpus.spec
    r = np.random.default_rng(0)
    nv = r.normal(size=(20, spec.d)).astype(np.float32)
    nf = corpus.filters[:20].copy()
    outs = []
    for use_pallas in (False, True):
        cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, use_pallas=use_pallas)
        idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters),
                    cfg)
        eng = FCVIEngine(idx, EngineConfig(k=5, batch_size=16,
                                           compact_threshold=64))
        eng.insert(nv, nf)
        assert eng.delta_size() == 20
        outs.append(eng.search(np.asarray(q), np.asarray(fq)))
    _assert_same(outs[0], outs[1])


def test_engine_delta_surfaces_inserted_rows(data):
    """A query identical to an inserted row must retrieve it from the delta
    through the batched merge path (exercises merge_topk + combined_score)."""
    corpus, _, _ = data
    spec = corpus.spec
    r = np.random.default_rng(1)
    nv = r.normal(size=(8, spec.d)).astype(np.float32)
    nf = corpus.filters[:8].copy()
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, use_pallas=True)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    eng = FCVIEngine(idx, EngineConfig(k=5, batch_size=16,
                                       compact_threshold=64))
    base = eng.index.size
    eng.insert(nv, nf)
    _, ids = eng.search(nv[:3], nf[:3])
    assert (ids >= base).any()
