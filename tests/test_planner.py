"""Golden plan-choice tests for the selectivity-aware planner, plus the
steady-state no-retrace contract of the filtered serving path.

The planner is a pure performance decision (every plan is exact — see
tests/test_filter_oracle.py), so what these tests pin down is the POLICY:
which selectivity band maps to which physical plan on which (backend,
topology, storage) — and the jit-key discipline: predicate bounds,
IN-lists, and eligibility masks are data operands, so serving a stream of
DIFFERENT predicates under one plan must not retrace anything.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FCVIConfig, build
from repro.core.filters import F, compile_predicate
from repro.serve import engine as engine_mod
from repro.serve.engine import EngineConfig, FCVIEngine
from repro.serve.planner import (PLAN_FOLD, PLAN_MASK, PLAN_ROUTED,
                                 ColumnStats, QueryPlanner)

M = 4
NAMES = tuple(f"f{j}" for j in range(M))


def make_attrs(n=2000, seed=0):
    rng = np.random.default_rng(seed)
    attrs = rng.normal(size=(n, M)).astype(np.float32)
    attrs[:, 2] = rng.integers(0, 8, size=n).astype(np.float32)  # categorical
    return attrs


def planner_for(attrs, *, backend="flat", storage_fp32=True, sharded=False):
    return QueryPlanner.build(attrs, backend=backend,
                              storage_fp32=storage_fp32, sharded=sharded)


def cp_of(pred):
    return compile_predicate(pred, NAMES)


# ---------------------------------------------------------------------------
# Selectivity estimation
# ---------------------------------------------------------------------------

def test_histogram_selectivity_tracks_truth():
    attrs = make_attrs()
    pl = planner_for(attrs)
    for lo, hi in [(-0.5, 0.5), (-3.0, 3.0), (1.0, 2.0)]:
        est = pl.selectivity(cp_of(F.range("f0", lo, hi)))
        true = ((attrs[:, 0] >= lo) & (attrs[:, 0] <= hi)).mean()
        assert abs(est - true) < 0.05, (lo, hi, est, true)


def test_categorical_value_counts_are_exact():
    attrs = make_attrs()
    pl = planner_for(attrs)
    assert pl.columns[2].value_counts is not None  # 8 distinct -> exact
    est = pl.selectivity(cp_of(F.isin("f2", [0.0, 3.0])))
    true = np.isin(attrs[:, 2], [0.0, 3.0]).mean()
    assert abs(est - true) < 1e-6
    # a value that never occurs estimates zero
    assert pl.selectivity(cp_of(F.eq("f2", 99.0))) == 0.0


def test_conjunction_multiplies_under_independence():
    attrs = make_attrs()
    pl = planner_for(attrs)
    a = pl.selectivity(cp_of(F.range("f0", -0.5, 0.5)))
    b = pl.selectivity(cp_of(F.range("f1", -0.5, 0.5)))
    ab = pl.selectivity(cp_of(F.range("f0", -0.5, 0.5)
                              & F.range("f1", -0.5, 0.5)))
    assert abs(ab - a * b) < 1e-6


# ---------------------------------------------------------------------------
# Golden plan choice per (selectivity band, backend, topology, storage)
# ---------------------------------------------------------------------------

BROAD = F.range("f0", -3.0, 3.0)              # sel ~ 0.997
MID = F.range("f0", -0.5, 0.5)                # sel ~ 0.38
NARROW = F.eq("f2", 5.0)                      # sel ~ 0.125
VERY_NARROW = F.range("f0", 3.0, 4.0)         # sel ~ 0.001
CONJ_BROAD = F.range("f0", -3.0, 3.0) & F.range("f1", -3.0, 3.0)


@pytest.mark.parametrize("pred,backend,sharded,storage_fp32,want", [
    # flat fp32 meshless: fold for broad single-attr, mask otherwise
    (BROAD, "flat", False, True, PLAN_FOLD),
    (MID, "flat", False, True, PLAN_MASK),
    (VERY_NARROW, "flat", False, True, PLAN_MASK),   # nothing to route
    (CONJ_BROAD, "flat", False, True, PLAN_MASK),    # fold is single-attr
    # reduced storage: the fold certificate needs the fp32 scan
    (BROAD, "flat", False, False, PLAN_MASK),
    # IVF: routed for selective, mask otherwise (no fold off flat)
    (VERY_NARROW, "ivf", False, True, PLAN_ROUTED),
    (BROAD, "ivf", False, True, PLAN_MASK),
    (NARROW, "ivf", False, True, PLAN_MASK),         # 0.125 > routed_max_sel
    # sharded flat: shard lax.cond skip makes routing capable
    (VERY_NARROW, "flat", True, True, PLAN_ROUTED),
    (BROAD, "flat", True, True, PLAN_FOLD),
])
def test_golden_plan_choice(pred, backend, sharded, storage_fp32, want):
    pl = planner_for(make_attrs(), backend=backend, sharded=sharded,
                     storage_fp32=storage_fp32)
    assert pl.choose(cp_of(pred)) == want


def test_kp_scales_inversely_with_fold_selectivity():
    pl = planner_for(make_attrs())
    kp_broad = pl.kp_for(PLAN_FOLD, cp_of(BROAD), k=10)
    kp_mid = pl.kp_for(PLAN_FOLD, cp_of(MID), k=10)
    assert kp_broad < kp_mid            # rarer matches -> wider fold window
    assert kp_broad >= 40               # >= 4k headroom for the certificate
    kp_mask = pl.kp_for(PLAN_MASK, cp_of(MID), k=10)
    assert kp_mask == 18                # k + CANDIDATE_PAD: scan is masked


def test_engine_plan_counters_follow_choice():
    rng = np.random.default_rng(3)
    n = 600
    v = rng.normal(size=(n, 16)).astype(np.float32)
    a = make_attrs(n=n, seed=3)
    idx = build(jnp.asarray(v), jnp.asarray(a),
                FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend="flat"))
    eng = FCVIEngine(idx, EngineConfig(k=5, batch_size=8), attributes=a)
    q = rng.normal(size=(4, 16)).astype(np.float32)
    eng.search(q, filter=BROAD)
    assert eng.stats.plan_fold == 4
    eng.search(q, filter=MID)
    assert eng.stats.plan_mask == 4
    assert eng.stats.filtered_queries == 8


# ---------------------------------------------------------------------------
# Jit-key discipline: steady-state filtered serving never retraces
# ---------------------------------------------------------------------------

def test_no_retrace_across_predicate_values():
    """After one warmup search per plan, a stream of DIFFERENT predicates
    (bounds, IN-lists, conjunction shapes all varying, same batch bucket)
    must not trigger a single new trace: predicate state is data."""
    rng = np.random.default_rng(5)
    n = 500
    v = rng.normal(size=(n, 16)).astype(np.float32)
    a = make_attrs(n=n, seed=5)
    idx = build(jnp.asarray(v), jnp.asarray(a),
                FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend="flat"))
    eng = FCVIEngine(idx, EngineConfig(k=5, batch_size=8), attributes=a)
    q = rng.normal(size=(8, 16)).astype(np.float32)

    # warmup: one trace per (plan, shape) key
    eng.search(q, filter=F.range("f0", -0.4, 0.4), plan="mask")
    eng.search(q, filter=F.isin("f2", [1.0, 2.0]), plan="mask")
    tc = engine_mod.trace_count()
    for step in range(6):
        lo = -0.5 - 0.1 * step
        preds = [F.range("f0", lo, -lo),
                 F.isin("f2", [float(step % 8), float((step + 3) % 8)]),
                 F.range("f1", lo, 1.0) & F.eq("f2", float(step % 8))]
        for p in preds:
            eng.search(q, filter=p, plan="mask")
    assert engine_mod.trace_count() == tc, (
        f"{engine_mod.trace_count() - tc} retraces in steady state")


def test_no_retrace_fold_same_band():
    """Fold keys on the pow-2 candidate width: predicates in the same
    selectivity band reuse one trace."""
    rng = np.random.default_rng(6)
    n = 512
    v = rng.normal(size=(n, 16)).astype(np.float32)
    a = make_attrs(n=n, seed=6)
    idx = build(jnp.asarray(v), jnp.asarray(a),
                FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend="flat"))
    eng = FCVIEngine(idx, EngineConfig(k=5, batch_size=8), attributes=a)
    q = rng.normal(size=(8, 16)).astype(np.float32)
    eng.search(q, filter=F.range("f0", -3.0, 3.0), plan="fold")
    tc = engine_mod.trace_count()
    fb = eng.stats.filtered_fallbacks
    for lo in (-3.1, -2.9, -2.8, -3.3):
        eng.search(q, filter=F.range("f0", lo, -lo), plan="fold")
    if eng.stats.filtered_fallbacks == fb:    # no new fallback sub-batches
        assert engine_mod.trace_count() == tc


def test_column_stats_degenerate_inputs():
    """Constant and tiny columns must not divide by zero or crash."""
    st = ColumnStats.build(np.zeros((50,), np.float32))
    assert st.sel_range(-1.0, 1.0) == pytest.approx(1.0)
    assert st.sel_range(0.5, 1.0) == 0.0
    st1 = ColumnStats.build(np.array([2.0], np.float32))
    assert st1.sel_values([2.0]) == pytest.approx(1.0)
    pl = QueryPlanner(columns=[st], n=0, backend="flat", storage_fp32=True,
                      sharded=False)
    assert pl.kp_for(PLAN_FOLD, cp_of(F.range("f0", 0.0, 1.0)), 5) == 5
