"""Gradient compression: unbiasedness, error bounds, cross-pod sync."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # graceful skip when not installed
from hypothesis import given, settings, strategies as st

from repro.distributed.compression import (quantize_int8, dequantize_int8,
                                           compress_ratio, BLOCK)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4000), st.integers(0, 2**31 - 1),
       st.floats(1e-3, 1e3))
def test_roundtrip_error_bounded(n, seed, scale):
    r = np.random.default_rng(seed)
    x = jnp.asarray((scale * r.normal(size=(n,))).astype(np.float32))
    codes, scales, pad = quantize_int8(x, jax.random.PRNGKey(seed))
    y = dequantize_int8(codes, scales, pad, x.shape, x.dtype)
    # per-element error bounded by its block scale (one quantization step)
    blocks, _ = x.reshape(-1)[: (n // BLOCK) * BLOCK].reshape(-1, BLOCK), 0
    err = np.abs(np.asarray(y - x))
    per_block_scale = np.asarray(scales)
    limit = np.repeat(per_block_scale, BLOCK)[:n] + 1e-12
    assert (err <= limit * 1.0001).all()


def test_stochastic_rounding_unbiased():
    x = jnp.full((BLOCK,), 0.3)  # sits between quantization steps
    outs = []
    for i in range(400):
        codes, scales, pad = quantize_int8(x, jax.random.PRNGKey(i))
        outs.append(np.asarray(dequantize_int8(codes, scales, pad,
                                               x.shape, x.dtype)))
    mean = np.mean(outs)
    assert abs(mean - 0.3) < 2e-3, f"biased: {mean}"


def test_compress_ratio():
    x = jnp.zeros((1024, 1024))
    assert compress_ratio(x) < 0.27  # ~4x smaller than f32


def test_zero_and_extreme_values():
    x = jnp.zeros((BLOCK,))
    codes, scales, pad = quantize_int8(x, jax.random.PRNGKey(0))
    y = dequantize_int8(codes, scales, pad, x.shape, x.dtype)
    np.testing.assert_allclose(np.asarray(y), 0.0)
    x2 = jnp.asarray([1e30, -1e30] * (BLOCK // 2))
    codes, scales, pad = quantize_int8(x2, jax.random.PRNGKey(0))
    y2 = dequantize_int8(codes, scales, pad, x2.shape, x2.dtype)
    assert np.isfinite(np.asarray(y2)).all()


@pytest.mark.slow
def test_cross_pod_sync_subprocess():
    """8 fake devices as a (2, 2, 2) pod mesh: sync ~= exact mean/sum."""
    import os, subprocess, sys, textwrap
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.compression import cross_pod_grad_sync
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*3)
        sync = cross_pod_grad_sync(mesh)
        g = jnp.asarray(np.random.default_rng(0)
                        .normal(size=(512,)).astype(np.float32))
        out = jax.jit(lambda g, k: sync(g, k))(g, jax.random.PRNGKey(0))
        exact = g * 8  # psum over all 8 devices of identical replicas
        rel = float(jnp.max(jnp.abs(out - exact)) / jnp.max(jnp.abs(exact)))
        assert rel < 0.02, rel
        print("cross-pod sync OK, rel err", rel)
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
