"""Properties of the psi transformation (paper §4.1, Thm 5.1/5.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # graceful skip when not installed
from hypothesis import given, settings, strategies as st

from repro.core import theory
from repro.core.transform import (Normalizer, fit_transform, psi_cluster,
                                  psi_embedding, psi_partition,
                                  psi_partition_inverse, tiled_filter)
from repro.kernels.ref import partition_matrix

DIMS = st.sampled_from([(8, 2), (16, 4), (32, 8), (64, 4), (12, 3)])


@settings(max_examples=25, deadline=None)
@given(DIMS, st.floats(1.0, 8.0), st.integers(0, 2**31 - 1))
def test_thm51_same_filter_distance_preserved(dims, alpha, seed):
    """Thm 5.1 case 1: identical filters -> distances exactly preserved."""
    d, m = dims
    r = np.random.default_rng(seed)
    va, vb = r.normal(size=(2, d)).astype(np.float32)
    f = r.normal(size=(m,)).astype(np.float32)
    ta = psi_partition(jnp.asarray(va), jnp.asarray(f), alpha)
    tb = psi_partition(jnp.asarray(vb), jnp.asarray(f), alpha)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(ta - tb)),
        np.linalg.norm(va - vb), rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(DIMS, st.integers(0, 2**31 - 1))
def test_thm51_closed_form_distance(dims, seed):
    """The expansion in Thm 5.1's proof matches the actual distance."""
    d, m = dims
    r = np.random.default_rng(seed)
    va, vb = r.normal(size=(2, d)).astype(np.float32)
    fa, fb = r.normal(size=(2, m)).astype(np.float32)
    alpha = 2.0
    ta = psi_partition(jnp.asarray(va), jnp.asarray(fa), alpha)
    tb = psi_partition(jnp.asarray(vb), jnp.asarray(fb), alpha)
    actual = float(jnp.sum((ta - tb) ** 2))
    closed = float(theory.transformed_sq_distance(
        jnp.asarray(va), jnp.asarray(vb), jnp.asarray(fa), jnp.asarray(fb), alpha))
    np.testing.assert_allclose(actual, closed, rtol=1e-4)


def test_quadratic_filter_influence():
    """Thm 5.1: filter-difference term grows quadratically with alpha."""
    r = np.random.default_rng(1)
    v = jnp.asarray(r.normal(size=(16,)).astype(np.float32))
    fa = jnp.asarray(r.normal(size=(4,)).astype(np.float32))
    fb = jnp.asarray(r.normal(size=(4,)).astype(np.float32))
    dists = []
    for alpha in (1.0, 2.0, 4.0):
        ta = psi_partition(v, fa, alpha)
        tb = psi_partition(v, fb, alpha)
        dists.append(float(jnp.sum((ta - tb) ** 2)))
    # same v: distance^2 = (d/m) a^2 ||df||^2 exactly -> ratios 4x
    assert dists[1] / dists[0] == pytest.approx(4.0, rel=1e-4)
    assert dists[2] / dists[1] == pytest.approx(4.0, rel=1e-4)


def test_partition_equals_matrix_form():
    """psi_partition == v - alpha * f @ P (the kernel's matmul form)."""
    r = np.random.default_rng(2)
    v = jnp.asarray(r.normal(size=(5, 24)).astype(np.float32))
    f = jnp.asarray(r.normal(size=(5, 4)).astype(np.float32))
    P = partition_matrix(24, 4)
    np.testing.assert_allclose(
        np.asarray(psi_partition(v, f, 3.0)),
        np.asarray(v - 3.0 * f @ P), rtol=1e-5)


def test_partition_inverse():
    r = np.random.default_rng(3)
    v = jnp.asarray(r.normal(size=(7, 20)).astype(np.float32))
    f = jnp.asarray(r.normal(size=(7, 5)).astype(np.float32))
    t = psi_partition(v, f, 2.5)
    back = psi_partition_inverse(t, f, 2.5)
    np.testing.assert_allclose(np.asarray(back), np.asarray(v), atol=1e-5)


def test_tiled_filter_identity():
    r = np.random.default_rng(4)
    v = jnp.asarray(r.normal(size=(3, 12)).astype(np.float32))
    f = jnp.asarray(r.normal(size=(3, 3)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(psi_partition(v, f, 1.5)),
        np.asarray(v - 1.5 * tiled_filter(f, 12)), rtol=1e-6)


def test_embedding_mode_defaults_to_partition():
    """With the default tiled-identity W, Eq. 7 reduces to Eq. 5."""
    r = np.random.default_rng(5)
    v = jnp.asarray(r.normal(size=(50, 16)).astype(np.float32))
    f = jnp.asarray(r.normal(size=(50, 4)).astype(np.float32))
    t_part = fit_transform(v, f, 2.0, "partition")
    t_emb = fit_transform(v, f, 2.0, "embedding")
    np.testing.assert_allclose(np.asarray(t_part.apply(v, f)),
                               np.asarray(t_emb.apply(v, f)), rtol=1e-4,
                               atol=1e-5)


def test_cluster_mode_uses_centers():
    r = np.random.default_rng(6)
    centers = 4.0 * r.normal(size=(4, 4)).astype(np.float32)
    labels = r.integers(0, 4, 200)
    f = (centers[labels] + 0.01 * r.normal(size=(200, 4))).astype(np.float32)
    v = r.normal(size=(200, 16)).astype(np.float32)
    tfm = fit_transform(jnp.asarray(v), jnp.asarray(f), 2.0, "cluster",
                        n_clusters=4, normalize=False)
    # two rows with the same cluster but different f must transform with the
    # SAME center -> their transformed difference equals raw difference
    same = np.nonzero(labels == labels[0])[0][:2]
    t = tfm.apply(jnp.asarray(v[same]), jnp.asarray(f[same]))
    np.testing.assert_allclose(
        np.asarray(t[0] - t[1]), v[same[0]] - v[same[1]], atol=1e-4)


def test_normalizer_standardizes():
    r = np.random.default_rng(7)
    x = (5.0 + 3.0 * r.normal(size=(4000, 6))).astype(np.float32)
    nrm = Normalizer.fit(jnp.asarray(x))
    y = np.asarray(nrm.apply(jnp.asarray(x)))
    np.testing.assert_allclose(y.mean(0), 0.0, atol=1e-3)
    np.testing.assert_allclose(y.std(0), 1.0, atol=1e-2)
    back = np.asarray(nrm.inverse(jnp.asarray(y)))
    np.testing.assert_allclose(back, x, rtol=1e-3, atol=1e-3)


def test_partition_requires_divisibility():
    v = jnp.zeros((2, 10))
    f = jnp.zeros((2, 3))
    with pytest.raises(ValueError):
        psi_partition(v, f, 1.0)
