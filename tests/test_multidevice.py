"""Multi-device correctness via subprocess (8 fake host devices, real
execution — validates shard_map search + sharded train step numerics)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_in_subprocess(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_sharded_search_matches_flat():
    run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.index import flat as flat_mod
        from repro.index.distributed import sharded_search_fn

        mesh = make_mesh((4, 2), ("data", "model"))
        r = np.random.default_rng(0)
        x = jnp.asarray(r.normal(size=(1024, 32)).astype(np.float32))
        q = jnp.asarray(r.normal(size=(16, 32)).astype(np.float32))
        sq = jnp.sum(x*x, -1)
        fn = jax.jit(sharded_search_fn(mesh, ("data", "model"), 10))
        xs = jax.device_put(x, NamedSharding(mesh, P(("data","model"), None)))
        sqs = jax.device_put(sq, NamedSharding(mesh, P(("data","model"))))
        v1, i1 = fn(xs, sqs, q)
        v2, i2 = flat_mod.search(flat_mod.build(x), q, 10)
        np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4, atol=1e-4)
        assert (np.asarray(i1) == np.asarray(i2)).mean() > 0.99
        print("sharded search OK")
    """)


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    run_in_subprocess("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.configs import get_config, reduced
        from repro.models import model as M
        from repro.train import loop as train_loop, optimizer as opt
        from repro.distributed.sharding import AxisRules, use_rules, param_spec_tree

        cfg = reduced(get_config("mistral-nemo-12b"))
        cfg = dataclasses.replace(cfg, n_layers=2, n_heads=4, n_kv_heads=2)
        adamw = opt.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        step = train_loop.make_train_step(cfg, adamw)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        state = opt.init(params)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)}

        # single device reference
        p_ref, _, m_ref = jax.jit(step)(params, state, batch)

        # 4x2 mesh sharded
        mesh = make_mesh((4, 2), ("data", "model"))
        rules = AxisRules(mesh)
        with use_rules(rules):
            specs = param_spec_tree(params, rules)
            to_sh = lambda t, s: jax.tree.map(
                lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)), t, s,
                is_leaf=lambda x: hasattr(x, "shape"))
            ps = to_sh(params, specs)
            ss = opt.AdamWState(step=state.step, mu=to_sh(state.mu, specs),
                                nu=to_sh(state.nu, specs),
                                master=to_sh(state.master, specs))
            bs = {"tokens": jax.device_put(batch["tokens"],
                                           NamedSharding(mesh, P("data", None)))}
            p_sh, _, m_sh = jax.jit(step)(ps, ss, bs)

        assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 5e-2, \\
            (float(m_ref["loss"]), float(m_sh["loss"]))
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)))
        assert d < 5e-2, f"sharded-vs-single param drift {d}"
        print("sharded train step OK, loss", float(m_sh["loss"]))
    """)


@pytest.mark.slow
def test_seq_parallel_attention_core():
    """The shard_map sequence-parallel attention (indivisible-heads path)
    must agree with the plain path."""
    run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.models.attention import chunked_attention
        from repro.distributed.sharding import AxisRules, use_rules

        mesh = make_mesh((2, 4), ("data", "model"))
        r = np.random.default_rng(0)
        q = jnp.asarray(r.normal(size=(2, 64, 6, 16)).astype(np.float32))
        k = jnp.asarray(r.normal(size=(2, 64, 2, 16)).astype(np.float32))
        v = jnp.asarray(r.normal(size=(2, 64, 2, 16)).astype(np.float32))
        plain = chunked_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
        rules = AxisRules(mesh, {"attn_core_seq_shard": "model",
                                 "heads": None, "head_dim": "model"})
        with use_rules(rules):
            f = jax.jit(lambda q, k, v: chunked_attention(
                q, k, v, causal=True, q_chunk=16, kv_chunk=16))
            sp = f(q, k, v)
        np.testing.assert_allclose(np.asarray(plain, np.float32),
                                   np.asarray(sp, np.float32), rtol=2e-2, atol=2e-2)
        print("seq-parallel attention OK")
    """)
