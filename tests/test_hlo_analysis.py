"""HLO analyzer: loop expansion correctness on freshly compiled toy modules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def _flops_of(fn, *sds):
    compiled = jax.jit(fn).lower(*sds).compile()
    return H.analyze(compiled.as_text())["flops"]


def test_scan_flops_match_unrolled():
    """The whole point of the analyzer: an 8-step scan must report the same
    dot FLOPs as the unrolled version (XLA's cost_analysis reports 1/8)."""
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)

    def f_scan(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    def f_unroll(w, x):
        for _ in range(8):
            x = jnp.tanh(x @ w)
        return x

    fs = _flops_of(f_scan, w, x)
    fu = _flops_of(f_unroll, w, x)
    expect = 8 * 2 * 64 * 128 * 128
    assert fs == pytest.approx(expect, rel=0.05), fs
    assert fu == pytest.approx(expect, rel=0.05), fu


def test_dot_flops_with_batch_dims():
    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    f = _flops_of(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), a, b)
    assert f == pytest.approx(2 * 4 * 32 * 16 * 64, rel=0.05), f


def test_shape_bytes():
    assert H.shape_bytes("f32[4,8]{1,0}") == 128
    assert H.shape_bytes("bf16[10]") == 20
    assert H.shape_bytes("(f32[2,2], s32[3])") == 28
    assert H.shape_bytes("pred[]") == 1  # zero-dim


def test_roofline_dominant():
    t = H.roofline_terms(197e12, 819e9 * 2, 0.0)
    assert t["dominant"] == "memory"
    t2 = H.roofline_terms(197e12 * 3, 819e9, 50e9)
    assert t2["dominant"] == "compute"


def test_collectives_detected_in_sharded_module():
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh  # papers over AxisType API skew
    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs >1 device")
    mesh = make_mesh((n,), ("d",))

    def f(w, x):
        return jnp.sum(jnp.tanh(x @ w))

    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8 * n, 64), jnp.float32)
    g = jax.jit(jax.grad(f), in_shardings=(
        NamedSharding(mesh, P(None, "d")), NamedSharding(mesh, P("d", None))))
    res = H.analyze(g.lower(w, x).compile().as_text())
    assert res["collective_bytes"] > 0
