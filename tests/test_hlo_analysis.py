"""HLO analyzer: loop expansion correctness on freshly compiled toy modules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def _flops_of(fn, *sds):
    compiled = jax.jit(fn).lower(*sds).compile()
    return H.analyze(compiled.as_text())["flops"]


def test_scan_flops_match_unrolled():
    """The whole point of the analyzer: an 8-step scan must report the same
    dot FLOPs as the unrolled version (XLA's cost_analysis reports 1/8)."""
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)

    def f_scan(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    def f_unroll(w, x):
        for _ in range(8):
            x = jnp.tanh(x @ w)
        return x

    fs = _flops_of(f_scan, w, x)
    fu = _flops_of(f_unroll, w, x)
    expect = 8 * 2 * 64 * 128 * 128
    assert fs == pytest.approx(expect, rel=0.05), fs
    assert fu == pytest.approx(expect, rel=0.05), fu


def test_dot_flops_with_batch_dims():
    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    f = _flops_of(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), a, b)
    assert f == pytest.approx(2 * 4 * 32 * 16 * 64, rel=0.05), f


def test_shape_bytes():
    assert H.shape_bytes("f32[4,8]{1,0}") == 128
    assert H.shape_bytes("bf16[10]") == 20
    assert H.shape_bytes("(f32[2,2], s32[3])") == 28
    assert H.shape_bytes("pred[]") == 1  # zero-dim


def test_roofline_dominant():
    t = H.roofline_terms(197e12, 819e9 * 2, 0.0)
    assert t["dominant"] == "memory"
    t2 = H.roofline_terms(197e12 * 3, 819e9, 50e9)
    assert t2["dominant"] == "compute"


@pytest.mark.slow
def test_gather_free_step_has_no_all_reduce():
    """Acceptance for the gather-free re-rank: the compiled sharded step
    contains NO all-reduce collective (the mask+psum candidate gather it
    replaces compiles to one), while the legacy step still does. Runs on 8
    forced host devices in a subprocess so the mesh is real."""
    import os
    import subprocess
    import sys
    import textwrap
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import FCVIConfig, build
    from repro.data.synthetic import CorpusSpec, make_corpus, sample_queries
    from repro.launch.mesh import make_mesh
    from repro.launch import hlo_analysis as H
    from repro.serve.engine import EngineConfig, FCVIEngine

    assert len(jax.devices()) == 8
    spec = CorpusSpec(n=1000, d=64, n_categories=5, n_numeric=3, seed=2)
    corpus = make_corpus(spec)
    q, fq = sample_queries(corpus, 5, seed=3)
    mesh = make_mesh((8, 1), ("data", "model"))
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend="flat")
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)

    def step_hlo(gather_free):
        eng = FCVIEngine(idx, EngineConfig(k=5, batch_size=16,
                                           gather_free=gather_free),
                         mesh=mesh)
        eng.search(np.asarray(q), np.asarray(fq))   # populate the step cache
        sh = eng._sharded
        (key,) = [kk for kk in sh._steps if kk[7] == gather_free]
        fn = sh._steps[key]
        b = eng.cfg.batch_size
        args = (sh.index.transform,) + sh._slab_args(False, False)
        args += sh._rows_payload() if gather_free else (sh.vectors_n,
                                                        sh.filters_n)
        args += (jnp.zeros((b, spec.d), jnp.float32),
                 jnp.zeros((b, fq.shape[-1]), jnp.float32))
        return fn.lower(*args).compile().as_text()

    gf = H.collective_stats(step_hlo(True))
    lg = H.collective_stats(step_hlo(False))
    assert not any("all-reduce" in op for op in gf), gf
    assert any("all-reduce" in op for op in lg), lg
    print("gather-free step collective-free OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"


def test_collectives_detected_in_sharded_module():
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh  # papers over AxisType API skew
    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs >1 device")
    mesh = make_mesh((n,), ("d",))

    def f(w, x):
        return jnp.sum(jnp.tanh(x @ w))

    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8 * n, 64), jnp.float32)
    g = jax.jit(jax.grad(f), in_shardings=(
        NamedSharding(mesh, P(None, "d")), NamedSharding(mesh, P("d", None))))
    res = H.analyze(g.lower(w, x).compile().as_text())
    assert res["collective_bytes"] > 0
