"""Routed sharded serving: parity with dense serving + routing machinery.

The contract under test: ``routing="routed"`` is a pure deployment knob —
for any mesh, flat (cluster placement) or IVF, kernels on or off, with or
without a live delta buffer, a routed engine returns top-k ids and scores
IDENTICAL to the dense-sharded engine (and therefore to the meshless one).
IVF routing is exact by construction (probed lists are wholly owned);
flat routing is certified per query by the ball-bound clipping check, with
flagged queries transparently re-run dense. Also covered: the routing
tables' checkpoint round-trip (save on 8 devices, restore on 2), router
edge cases (all probes on one shard; filters matching no cluster), the
placement/affinity layout invariants, and no-retrace steady state.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import FCVIConfig, build
from repro.launch.mesh import make_mesh
from repro.data.synthetic import CorpusSpec, make_corpus, sample_queries
from repro.index.distributed import affinity_group_layout
from repro.serve.engine import EngineConfig, FCVIEngine

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_in_subprocess(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.fixture(scope="module")
def data():
    spec = CorpusSpec(n=1000, d=64, n_categories=5, n_numeric=3, seed=2)
    corpus = make_corpus(spec)
    q, fq = sample_queries(corpus, 5, seed=3)
    return corpus, np.asarray(q), np.asarray(fq)


def _assert_identical(a, b):
    (s0, i0), (s1, i1) = a, b
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


# ---------------------------------------------------------------------------
# Fast in-process cases (1-device mesh + host-side layout/validation logic)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["flat", "ivf"])
def test_routed_one_device_mesh_identical(data, backend):
    """On a 1-shard mesh routing is a no-op and must stay bit-identical to
    the meshless engine, including the trivial route-mask/flag outputs."""
    corpus, q, fq = data
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend=backend, nlist=16,
                     nprobe=4)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    ek = dict(k=5, batch_size=16)
    e0 = FCVIEngine(idx, EngineConfig(**ek))
    e1 = FCVIEngine(idx, EngineConfig(**ek),
                    mesh=make_mesh((1, 1), ("data", "model")),
                    placement="cluster", routing="routed")
    _assert_identical(e0.search(q, fq), e1.search(q, fq))
    assert e1.stats.routed_batches > 0
    assert e1.stats.shard_skip_rate == 0.0      # one shard: nothing to skip


def test_routed_requires_mesh_and_cluster_placement(data):
    corpus, _, _ = data
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters),
                FCVIConfig(backend="flat"))
    with pytest.raises(ValueError, match="requires a device mesh"):
        FCVIEngine(idx, routing="routed")
    with pytest.raises(ValueError, match="placement='cluster'"):
        FCVIEngine(idx, mesh=make_mesh((1, 1), ("data", "model")),
                   routing="routed", placement="contiguous")
    with pytest.raises(ValueError, match="routing must be"):
        FCVIEngine(idx, mesh=make_mesh((1, 1), ("data", "model")),
                   routing="sideways")


def test_affinity_group_layout_invariants():
    """Affinity packing respects slot capacity, assigns every group exactly
    once, and co-locates nearby groups (two well-separated blobs of group
    centers must not share shards more than the balance caps force)."""
    r = np.random.default_rng(0)
    blob_a = r.normal(size=(12, 8)).astype(np.float32)
    blob_b = r.normal(size=(12, 8)).astype(np.float32) + 50.0
    centers = np.concatenate([blob_a, blob_b])
    sizes = np.full((24,), 10, np.int64)
    shard_of = affinity_group_layout(centers, sizes, 4, slot_capacity=6)
    assert shard_of.shape == (24,) and (shard_of < 4).all()
    assert (np.bincount(shard_of, minlength=4) <= 6).all()
    # groups of one blob never share a shard with the other blob's groups
    shards_a = set(shard_of[:12].tolist())
    shards_b = set(shard_of[12:].tolist())
    assert not (shards_a & shards_b)


def test_affinity_layout_degenerate_shapes():
    """Fewer groups than shards and 1-shard meshes stay total."""
    c = np.random.default_rng(1).normal(size=(3, 4)).astype(np.float32)
    s = np.asarray([5, 1, 2])
    assert (affinity_group_layout(c, s, 1) == 0).all()
    a = affinity_group_layout(c, s, 8)
    assert len(set(a.tolist())) == 3          # one group per shard


def test_one_shard_cluster_slab_has_no_router_tables(data):
    """The 1-shard degenerate case of cluster placement must not fabricate
    routing tables (the routed step then takes its trivial no-op branch)."""
    corpus, _, _ = data
    from repro.distributed.sharding import AxisRules

    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters),
                FCVIConfig(backend="flat"))
    mesh = make_mesh((1, 1), ("data", "model"))
    slab = idx.backend.slab().shard(mesh, AxisRules(mesh),
                                    placement="cluster")
    assert slab.router_centers is None and slab.cluster_to_shard is None


# ---------------------------------------------------------------------------
# Multi-shard cases (subprocess with 8 forced host devices)
# ---------------------------------------------------------------------------

_SUBPROCESS_PRELUDE = """
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import FCVIConfig, build
    from repro.data.synthetic import CorpusSpec, make_corpus, sample_queries
    from repro.launch.mesh import make_mesh
    from repro.serve.engine import EngineConfig, FCVIEngine

    assert len(jax.devices()) == 8
    spec = CorpusSpec(n=1000, d=64, n_categories=5, n_numeric=3, seed=2)
    corpus = make_corpus(spec)
    q, fq = sample_queries(corpus, 5, seed=3)
    q, fq = np.asarray(q), np.asarray(fq)
    mesh = make_mesh((8, 1), ("data", "model"))

    def engines(backend, use_pallas, routing="routed", **ekw):
        cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend=backend,
                         nlist=16, nprobe=4, use_pallas=use_pallas)
        idx = build(jnp.asarray(corpus.vectors),
                    jnp.asarray(corpus.filters), cfg)
        ek = dict(k=5, batch_size=16, compact_threshold=256)
        ek.update(ekw)
        return (FCVIEngine(idx, EngineConfig(**ek)),
                FCVIEngine(idx, EngineConfig(**ek), mesh=mesh,
                           placement="cluster", routing="dense"),
                FCVIEngine(idx, EngineConfig(**ek), mesh=mesh,
                           placement="cluster", routing=routing))

    def check(a, b, tag):
        (s0, i0), (s1, i1) = a, b
        assert (np.asarray(i0) == np.asarray(i1)).all(), tag
        assert (np.asarray(s0) == np.asarray(s1)).all(), tag
"""


@pytest.mark.slow
def test_routed_eight_device_parity():
    """Acceptance: routed results on a forced 8-device mesh equal the dense-
    sharded AND meshless results exactly — flat + IVF, kernels on/off, with
    a live delta buffer, escalation fallback exercised."""
    run_in_subprocess(_SUBPROCESS_PRELUDE + """
    r = np.random.default_rng(0)
    nv = r.normal(size=(20, spec.d)).astype(np.float32)
    nf = corpus.filters[:20].copy()

    # routing-table soundness: every corpus row's ACTUAL shard appears in
    # its cluster's incidence row (the precondition of the clipping bound)
    from repro.core.clustering import assign
    _, _, er0 = engines("flat", False)
    slab = er0._sharded.slab
    labels = np.asarray(assign(
        jnp.asarray(er0.index.backend.vectors, jnp.float32),
        slab.router_centers))
    row_ids = np.asarray(slab.row_ids)          # slab order -> corpus id
    inc = np.asarray(slab.cluster_to_shard)
    for pos in range(len(row_ids)):
        cid = row_ids[pos]
        if cid < 0:
            continue
        assert inc[labels[cid], pos // slab.n_local] == 1.0, pos

    total_fallbacks = 0
    for backend in ("flat", "ivf"):
        for use_pallas in (False, True):
            e0, ed, er = engines(backend, use_pallas)
            assert er._sharded.n_shards == 8
            a, b, c = e0.search(q, fq), ed.search(q, fq), er.search(q, fq)
            check(a, c, (backend, use_pallas, "routed-vs-meshless"))
            check(b, c, (backend, use_pallas, "routed-vs-dense"))
            e0.insert(nv, nf); ed.insert(nv, nf); er.insert(nv, nf)
            for e in (e0, ed, er): e._cache.clear()
            check(e0.search(q, fq), er.search(q, fq),
                  (backend, use_pallas, "delta"))
            assert er.stats.routed_batches > 0
            total_fallbacks += er.stats.router_fallbacks
            assert er.stats.router_fallbacks == 0 or backend == "flat"
    # the flat clipping bound must actually fire somewhere on this tiny
    # corpus (k' ~ corpus scale), proving the dense fallback path ran
    assert total_fallbacks > 0

    # two-axis mesh: the router's shard linearization must agree with the
    # slab layout when the corpus axes span a 4x2 mesh
    from repro.distributed.sharding import AxisRules
    mesh42 = make_mesh((4, 2), ("data", "model"))
    rules = AxisRules(mesh42, {"corpus": ("data", "model"),
                               "ivf_lists": ("data", "model")})
    for backend in ("flat", "ivf"):
        cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend=backend,
                         nlist=16, nprobe=4)
        idx = build(jnp.asarray(corpus.vectors),
                    jnp.asarray(corpus.filters), cfg)
        e0 = FCVIEngine(idx, EngineConfig(k=5, batch_size=16))
        er = FCVIEngine(idx, EngineConfig(k=5, batch_size=16), mesh=mesh42,
                        rules=rules, placement="cluster", routing="routed")
        assert er._sharded.n_shards == 8 and len(er._sharded.axes) == 2
        check(e0.search(q, fq), er.search(q, fq), (backend, "4x2-routed"))
    print("routed 8-device parity OK, fallbacks:", total_fallbacks)
    """)


@pytest.mark.slow
def test_routed_fallback_forced_and_exact():
    """Queries placed midway between psi-clusters with an aggressive router
    (router_nprobe=1) force the clipping flag — results must STILL be
    identical to dense because flagged queries re-run dense."""
    run_in_subprocess(_SUBPROCESS_PRELUDE + """
    e0, ed, er = engines("flat", False, router_nprobe=1)
    rc = np.asarray(er._sharded.slab.router_centers)
    r = np.random.default_rng(3)
    pairs = r.integers(0, rc.shape[0], size=(8, 2))
    qm = ((rc[pairs[:, 0]] + rc[pairs[:, 1]]) / 2).astype(np.float32)
    # midway queries live in TRANSFORMED space; invert the normalizers so
    # the engine's own transform lands them back there (filter = zeros ->
    # psi fold shifts all queries identically: still midway)
    tfm = e0.index.transform
    q_raw = np.asarray(tfm.vec_norm.inverse(jnp.asarray(qm)))
    f_raw = np.asarray(
        tfm.filt_norm.inverse(jnp.zeros((8, corpus.filters.shape[1]))))
    check(e0.search(q_raw, f_raw), er.search(q_raw, f_raw), "midway")
    assert er.stats.router_fallbacks > 0, "no fallback was forced"
    print("forced fallbacks:", er.stats.router_fallbacks, "identical OK")
    """)


@pytest.mark.slow
def test_router_edge_cases():
    """Probes all on one shard (selective traffic) and filters matching no
    psi-cluster (far out-of-distribution) stay total and exact."""
    run_in_subprocess(_SUBPROCESS_PRELUDE + """
    for backend in ("flat", "ivf"):
        e0, ed, er = engines(backend, False)
        # (a) selective: queries drawn around ONE corpus row, its own filter
        r = np.random.default_rng(7)
        base_q = corpus.vectors[3] + 0.05 * r.normal(
            size=(6, spec.d)).astype(np.float32)
        base_f = np.repeat(corpus.filters[3:4], 6, axis=0)
        sig = er._sharded.route_signatures(base_q, base_f)
        bits = np.unpackbits(sig, axis=1)[:, :8]
        assert (bits.sum(axis=1) >= 1).all()
        if backend == "ivf":
            # nprobe=4 lists around one point: few shards, never zero
            assert bits.sum(axis=1).max() <= 4
        check(e0.search(base_q, base_f), er.search(base_q, base_f),
              (backend, "one-shard"))
        # (b) filter matching zero clusters: far out-of-support filters
        far_f = 25.0 * np.ones((5, corpus.filters.shape[1]), np.float32)
        sig = er._sharded.route_signatures(q, far_f)
        assert (np.unpackbits(sig, axis=1)[:, :8].sum(axis=1) >= 1).all()
        check(e0.search(q, far_f), er.search(q, far_f), (backend, "far"))
    print("router edge cases OK")
    """)


@pytest.mark.slow
def test_routed_ckpt_roundtrip_8_to_2():
    """Acceptance: the routing tables round-trip through the checkpoint —
    save a routed engine from an 8-device mesh, restore onto a 2-device
    mesh, serve identical routed results with the SAME router centers (no
    k-means re-run), with routing/placement restored from metadata."""
    run_in_subprocess(_SUBPROCESS_PRELUDE + """
    import tempfile
    mesh2 = make_mesh((2, 1), ("data", "model"))
    for backend in ("flat", "ivf"):
        e0, ed, er = engines(backend, False)
        r = np.random.default_rng(0)
        er.insert(r.normal(size=(20, spec.d)).astype(np.float32),
                  corpus.filters[:20].copy())
        want = er.search(q, fq)
        tmp = tempfile.mkdtemp()
        er.save(tmp, step=1)
        er2 = FCVIEngine.restore(tmp, mesh=mesh2)
        assert er2._routing == "routed" and er2._placement == "cluster"
        assert er2._sharded.n_shards == 2 and er2.delta_size() == 20
        if backend == "flat":
            assert np.array_equal(
                np.asarray(er2._sharded.slab.router_centers),
                np.asarray(er._sharded.slab.router_centers))
        check(want, er2.search(q, fq), (backend, "restore-2dev-routed"))
        er0 = FCVIEngine.restore(tmp)        # meshless: routing forced dense
        assert er0._sharded is None
        check(want, er0.search(q, fq), (backend, "restore-meshless"))
    print("routed ckpt roundtrip OK")
    """)


@pytest.mark.slow
def test_routed_step_does_not_retrace():
    """Steady-state routed batches must not recompile — the routed step
    jit-caches per (k, k', kd, delta, routed) signature like the dense one,
    and the dispatch-layer regrouping must not perturb trace shapes."""
    run_in_subprocess(_SUBPROCESS_PRELUDE + """
    from repro.serve import engine as engine_mod
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    eng = FCVIEngine(idx, EngineConfig(k=5, batch_size=16,
                                       compact_threshold=512,
                                       escalate_margin=-1.0,  # no escalation
                                       router_nprobe=32),     # no fallbacks
                     mesh=mesh, placement="cluster", routing="routed")
    qq, ff = sample_queries(corpus, 16, seed=9)
    eng.search(qq, ff)
    warm = engine_mod.trace_count()
    for seed in (10, 11, 12):
        qq, ff = sample_queries(corpus, 16, seed=seed)
        eng._cache.clear()
        eng.search(qq, ff)
    assert engine_mod.trace_count() == warm, "routed step retraced"
    assert eng.stats.router_fallbacks == 0
    print("routed no-retrace OK")
    """)
