"""Property-based differential oracle suite for the filter algebra.

The contract under test (the exactness anchor of the whole filter stack):
for ANY predicate expressible in ``repro.core.filters`` — range / equality /
IN-list / conjunctions over multiple attribute columns — and any corpus,
``FCVIEngine.search(q, filter=pred)`` returns the EXACT top-k by squared L2
over the eligible rows, and every physical plan (fold / mask / routed),
kernel dispatch (pallas on / off), and topology (meshless / sharded /
routed-sharded) produces BIT-IDENTICAL output for the same call.

Structure:
  * a numpy brute-force oracle (fp64 ordering over the dequantized stored
    rows, deterministic (d2, id) tie-break) checks semantic exactness;
  * forced-plan and planner-chosen calls are compared bitwise against each
    other (the cheap-but-strict cross-plan differential);
  * randomized (corpus, attribute table, predicate tree) cases come from
    ``hypothesis`` when it is installed (CI), else from a fixed-seed
    parametrized fallback running the SAME case body — both deterministic;
  * the multi-shard topologies run in a subprocess with 8 forced host
    devices, like tests/test_multidevice.py.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FCVIConfig, build
from repro.core import fcvi
from repro.core.filters import MAX_ISIN, F, compile_predicate
from repro.launch.mesh import make_mesh
from repro.serve.engine import EngineConfig, FCVIEngine

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:           # hypothesis is a CI dependency, not a runtime
    HAVE_HYPOTHESIS = False   # one: fall back to fixed-seed parametrization

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# Case generation (shared by the hypothesis and fallback entry points)
# ---------------------------------------------------------------------------

D = 16  # vector dim; m=4 attribute/filter columns (d % m == 0 for partition)
M = 4


def make_case(seed: int):
    """Deterministic (corpus, attrs, queries, predicate) from one seed.

    Attribute columns are a mix of continuous and low-cardinality
    categorical (so IN-list / equality clauses actually hit rows and the
    planner's value-count path is exercised); predicate bounds are drawn
    from the realized attribute values, so selectivity spans the whole
    range including empty and all-rows matches.
    """
    rng = np.random.default_rng(seed)
    n = int(rng.integers(40, 300))
    vectors = rng.normal(size=(n, D)).astype(np.float32)
    attrs = rng.normal(size=(n, M)).astype(np.float32)
    # columns 2..3 categorical: a handful of distinct float codes
    for j in (2, 3):
        card = int(rng.integers(2, 9))
        attrs[:, j] = rng.integers(0, card, size=n).astype(np.float32)
    queries = rng.normal(size=(int(rng.integers(1, 6)), D)).astype(np.float32)

    clauses = []
    for _ in range(int(rng.integers(1, 4))):
        j = int(rng.integers(0, M))
        name = f"f{j}"
        kind = rng.integers(0, 3)
        col = attrs[:, j]
        if kind == 0:          # range, bounds from data quantiles (+ slack)
            lo, hi = np.sort(rng.choice(col, size=2, replace=True))
            lo += rng.normal() * 0.1
            hi += rng.normal() * 0.1
            clauses.append(F.range(name, float(lo), float(hi)))
        elif kind == 1:        # equality against a realized value
            clauses.append(F.eq(name, float(rng.choice(col))))
        else:                  # IN-list over realized values
            sz = int(rng.integers(1, min(MAX_ISIN, 6)))
            vals = [float(v) for v in rng.choice(col, size=sz, replace=True)]
            clauses.append(F.isin(name, vals))
    pred = clauses[0]
    for c in clauses[1:]:
        pred = pred & c
    backend = ["flat", "ivf"][seed % 2]
    use_pallas = bool((seed // 2) % 2)
    return vectors, attrs, queries, pred, backend, use_pallas


def brute_force_oracle(engine, queries, pred, k, tie_tol=1e-4):
    """fp64 numpy filtered top-k over the engine's own fold-transformed
    queries and dequantized stored rows, (d2 asc, id asc) tie-break.

    Returns (scores, ids, ambiguous) in the engine's output convention.
    ``ambiguous`` flags top-k slots whose fp64 distance sits within
    ``tie_tol`` of a neighbor: there the ENGINE's fp32 arithmetic may
    legitimately order the tie the other way, so positional id equality is
    only asserted on unambiguous slots (score values are always checked)."""
    cp = compile_predicate(pred, engine._attr_names)
    elig = cp.eval_np(engine._attrs_np)
    q_t = np.asarray(fcvi.fold_queries(
        engine.index, jnp.asarray(np.asarray(queries, np.float32)),
        cp.fold_target_raw(engine._col_means)), np.float64)
    be = engine.index.backend
    rows = np.asarray(be.vectors, np.float64)
    if be.scales is not None:
        rows = rows * np.asarray(be.scales, np.float64)[:, None]
    n, b = rows.shape[0], q_t.shape[0]
    d2 = ((q_t[:, None, :] - rows[None, :, :]) ** 2).sum(-1)
    d2[:, ~elig] = np.inf
    ids = np.broadcast_to(np.arange(n), (b, n))
    order = np.lexsort((ids, d2), axis=-1)
    sd2 = np.take_along_axis(d2, order, axis=-1)         # (b, n) ascending
    if n < k:                                            # pad to k slots
        pad = np.full((b, k - n), np.inf)
        sd2 = np.concatenate([sd2, pad], axis=-1)
        order = np.concatenate(
            [order, np.zeros((b, k - n), order.dtype)], axis=-1)
    with np.errstate(invalid="ignore"):
        prev = np.concatenate([np.full((b, 1), -np.inf), sd2[:, :-1]], -1)
        nxt = np.concatenate([sd2[:, 1:], np.full((b, 1), np.inf)], -1)
        amb = (((sd2 - prev) < tie_tol) | ((nxt - sd2) < tie_tol))
    amb &= np.isfinite(sd2)
    top_d2, order, amb = sd2[:, :k], order[:, :k], amb[:, :k]
    dead = np.isinf(top_d2)
    scores = np.where(dead, -np.inf, -top_d2).astype(np.float32)
    out_ids = np.where(dead, -1, order).astype(np.int64)
    return scores, out_ids, amb


def plans_for(engine, pred):
    cp = compile_predicate(pred, engine._attr_names)
    plans = [None, "mask"]
    if engine.planner.fold_capable(cp):
        plans.append("fold")
    if engine.planner.routed_capable():
        plans.append("routed")
    return plans


def check_case(seed: int):
    vectors, attrs, queries, pred, backend, use_pallas = make_case(seed)
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend=backend, nlist=8,
                     nprobe=4, use_pallas=use_pallas)
    idx = build(jnp.asarray(vectors), jnp.asarray(attrs), cfg)
    eng = FCVIEngine(idx, EngineConfig(k=5, batch_size=8), attributes=attrs,
                     attr_names=[f"f{j}" for j in range(M)])
    want_s, want_i, amb = brute_force_oracle(eng, queries, pred, k=5)
    outs = {pl: eng.search(queries, filter=pred, plan=pl)
            for pl in plans_for(eng, pred)}
    for pl, (s, i) in outs.items():
        assert ((i == want_i) | amb).all(), (
            f"ids vs oracle (plan={pl}, seed={seed}, pred={pred}):\n"
            f"{i}\nvs\n{want_i}")
        np.testing.assert_allclose(
            s, want_s, rtol=1e-4, atol=1e-4,
            err_msg=f"scores vs oracle (plan={pl}, seed={seed})")
    base = outs[None]
    for pl, (s, i) in outs.items():  # cross-plan: BITWISE
        assert np.array_equal(s, base[0]) and np.array_equal(i, base[1]), (
            f"plan {pl} != planner choice bitwise (seed={seed}, pred={pred})")


# ---------------------------------------------------------------------------
# The property suite (hypothesis when available, seeded fallback otherwise)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @pytest.mark.property
    @settings(max_examples=25, deadline=None, derandomize=True)
    @given(seed=st.integers(min_value=0, max_value=50_000))
    def test_differential_oracle_property(seed):
        check_case(seed)

else:

    @pytest.mark.property
    @pytest.mark.parametrize("seed", list(range(12)))
    def test_differential_oracle_property(seed):
        check_case(seed)


# ---------------------------------------------------------------------------
# Deterministic edge cases (the zero-match bugfix and friends)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_engine():
    rng = np.random.default_rng(7)
    v = rng.normal(size=(120, D)).astype(np.float32)
    a = rng.normal(size=(120, M)).astype(np.float32)
    idx = build(jnp.asarray(v), jnp.asarray(a),
                FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend="flat"))
    eng = FCVIEngine(idx, EngineConfig(k=5, batch_size=8), attributes=a)
    q = rng.normal(size=(3, D)).astype(np.float32)
    return eng, a, q


def test_zero_match_returns_certified_empty(small_engine):
    """A predicate matching nothing must return (-inf, -1) rows — certified
    empty with coverage 1.0 — not padded id-0 garbage."""
    eng, a, q = small_engine
    s, i = eng.search(q, filter=F.range("f0", 100.0, 200.0))
    assert (i == -1).all()
    assert np.isneginf(s).all()
    assert eng.stats.last_coverage.all()
    # disjoint IN-lists compile to an always-false interval, same contract
    s, i = eng.search(q, filter=F.isin("f1", [1.0]) & F.isin("f1", [2.0]))
    assert (i == -1).all() and np.isneginf(s).all()


def test_single_row_match(small_engine):
    eng, a, q = small_engine
    s, i = eng.search(q, filter=F.eq("f0", float(a[17, 0])))
    assert (i[:, 0] == 17).all()
    assert (i[:, 1:] == -1).all()
    assert np.isfinite(s[:, 0]).all() and np.isneginf(s[:, 1:]).all()


def test_all_rows_match_equals_unfiltered_topk(small_engine):
    """An all-true predicate is plain exact L2 top-k over everything."""
    eng, a, q = small_engine
    pred = F.range("f0", -1e9, 1e9)
    ws, wi, amb = brute_force_oracle(eng, q, pred, k=5)
    s, i = eng.search(q, filter=pred)
    assert ((i == wi) | amb).all()
    assert (i >= 0).all()


def test_k_exceeds_eligible_pads_dead_slots(small_engine):
    eng, a, q = small_engine
    order = np.argsort(a[:, 0])
    lo, hi = float(a[order[0], 0]), float(a[order[2], 0])
    s, i = eng.search(q, filter=F.range("f0", lo, hi))
    n_match = int(((a[:, 0] >= lo) & (a[:, 0] <= hi)).sum())
    assert 1 <= n_match < 5
    assert ((i >= 0).sum(axis=1) == n_match).all()
    assert np.isneginf(s[:, n_match:]).all()


def test_unknown_attribute_rejected(small_engine):
    eng, _, q = small_engine
    with pytest.raises(ValueError, match="unknown attribute"):
        eng.search(q, filter=F.range("price", 0.0, 1.0))


def test_filter_and_filters_are_exclusive(small_engine):
    eng, a, q = small_engine
    with pytest.raises(ValueError, match="not both"):
        eng.search(q, a[:3, :], filter=F.range("f0", 0.0, 1.0))
    with pytest.raises(TypeError):
        eng.search(q)


def test_delta_rows_are_predicate_checked():
    """Pending (un-compacted) inserts participate in filtered search: their
    insert filters are their attribute values, eligible delta rows surface
    with ids >= index.size, ineligible ones never do."""
    rng = np.random.default_rng(11)
    v = rng.normal(size=(100, D)).astype(np.float32)
    a = rng.normal(size=(100, M)).astype(np.float32)
    idx = build(jnp.asarray(v), jnp.asarray(a),
                FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend="flat"))
    eng = FCVIEngine(idx, EngineConfig(k=4, batch_size=8,
                                       compact_threshold=10_000))
    q = rng.normal(size=(2, D)).astype(np.float32)
    pred = F.range("f0", 50.0, 60.0)  # nothing in the base corpus
    s, i = eng.search(q, filter=pred)
    assert (i == -1).all()
    nv = rng.normal(size=(3, D)).astype(np.float32)
    nf = a[:3].copy()
    nf[:, 0] = 55.0  # eligible delta rows
    eng.insert(nv, nf)
    s, i = eng.search(q, filter=pred)
    assert set(i[:, :3].ravel()) == {100, 101, 102}
    assert (i[:, 3] == -1).all()
    # after compaction the same rows answer under corpus ids (extend appends,
    # so they keep ids 100..102); scores are not compared across compaction —
    # the planner's column means (and so the fold target) legitimately move
    eng.compact()
    s2, i2 = eng.search(q, filter=pred)
    assert (np.sort(i2[:, :3], axis=1) == [100, 101, 102]).all()
    assert (i2[:, 3] == -1).all()


@pytest.mark.parametrize("storage", ["bfloat16", "int8"])
def test_reduced_storage_matches_oracle(storage):
    """mask plan over bf16 / int8 slabs: exact w.r.t. the dequantized stored
    rows (the oracle dequantizes the same way)."""
    rng = np.random.default_rng(13)
    v = rng.normal(size=(150, D)).astype(np.float32)
    a = rng.normal(size=(150, M)).astype(np.float32)
    idx = build(jnp.asarray(v), jnp.asarray(a),
                FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend="flat",
                           storage_dtype=storage))
    eng = FCVIEngine(idx, EngineConfig(k=5, batch_size=8), attributes=a)
    q = rng.normal(size=(3, D)).astype(np.float32)
    pred = F.range("f0", -0.7, 0.9) & F.range("f2", -2.0, 2.0)
    ws, wi, amb = brute_force_oracle(eng, q, pred, k=5)
    s, i = eng.search(q, filter=pred)
    assert ((i == wi) | amb).all()
    np.testing.assert_allclose(s, ws, rtol=1e-4, atol=1e-4)


def test_pq_backend_rejects_predicates():
    rng = np.random.default_rng(17)
    v = rng.normal(size=(256, D)).astype(np.float32)
    a = rng.normal(size=(256, M)).astype(np.float32)
    idx = build(jnp.asarray(v), jnp.asarray(a),
                FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend="pq", pq_m=8,
                           pq_ksub=16, pq_coarse=8))
    eng = FCVIEngine(idx, EngineConfig(k=5))
    with pytest.raises(ValueError, match="flat or ivf"):
        eng.search(rng.normal(size=(2, D)).astype(np.float32),
                   filter=F.range("f0", 0.0, 1.0))


def test_save_restore_preserves_attribute_table(tmp_path):
    rng = np.random.default_rng(19)
    v = rng.normal(size=(80, D)).astype(np.float32)
    a = rng.normal(size=(80, M)).astype(np.float32)
    idx = build(jnp.asarray(v), jnp.asarray(a),
                FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend="flat"))
    eng = FCVIEngine(idx, EngineConfig(k=5), attributes=a,
                     attr_names=["price", "stock", "cat", "region"])
    q = rng.normal(size=(2, D)).astype(np.float32)
    pred = F.range("price", -0.5, 0.5) & F.range("region", -2.0, 2.0)
    want = eng.search(q, filter=pred)
    eng.save(str(tmp_path), step=1)
    er = FCVIEngine.restore(str(tmp_path))
    assert er._attr_names == ("price", "stock", "cat", "region")
    got = er.search(q, filter=pred)
    np.testing.assert_array_equal(got[1], want[1])
    np.testing.assert_array_equal(got[0], want[0])


# ---------------------------------------------------------------------------
# 8-device sharded / routed topology matrix (subprocess, forced host devices)
# ---------------------------------------------------------------------------

def run_in_subprocess(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_sharded_topologies_bitwise_equal_8dev():
    """Meshless vs 8-shard sharded vs routed-sharded, flat and IVF, across
    forced plans: all bitwise equal, and equal to the fp64 oracle's ids."""
    out = run_in_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        import sys; sys.path.insert(0, {src!r})
        sys.path.insert(0, {tests!r})
        from repro.core import FCVIConfig, build
        from repro.serve.engine import EngineConfig, FCVIEngine
        from test_filter_oracle import (brute_force_oracle, make_case,
                                        plans_for, M)

        assert len(jax.devices()) == 8
        mesh = Mesh(np.array(jax.devices()), ("x",))
        checked = 0
        for seed in (0, 1, 2, 3, 6, 9):
            vectors, attrs, queries, pred, backend, use_pallas = \\
                make_case(seed)
            cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend=backend,
                             nlist=8, nprobe=4, use_pallas=use_pallas)
            idx = build(jnp.asarray(vectors), jnp.asarray(attrs), cfg)
            kw = dict(k=5, batch_size=8)
            e0 = FCVIEngine(idx, EngineConfig(**kw), attributes=attrs)
            e1 = FCVIEngine(idx, EngineConfig(**kw), attributes=attrs,
                            mesh=mesh)
            ws, wi, amb = brute_force_oracle(e0, queries, pred, k=5)
            outs = []
            for eng in (e0, e1):
                for pl in plans_for(eng, pred):
                    outs.append((eng is e1, pl,
                                 eng.search(queries, filter=pred, plan=pl)))
            s0, i0 = outs[0][2]
            assert ((i0 == wi) | amb).all(), seed
            for sharded, pl, (s, i) in outs:
                assert np.array_equal(s, s0) and np.array_equal(i, i0), (
                    seed, sharded, pl)
                checked += 1
        print("CASES", checked)
    """.format(src=SRC,
               tests=os.path.dirname(os.path.abspath(__file__))))
    assert "CASES" in out
