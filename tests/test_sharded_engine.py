"""Mesh-sharded serving parity + elastic checkpoint lifecycle.

The contract under test: the shard_map engine step (``repro.serve.sharded``)
is a pure DEPLOYMENT knob — for any mesh shape, flat or IVF, kernels on or
off, with or without a live delta buffer, it returns top-k ids and scores
IDENTICAL to the single-device jitted ``_batch_step``; and an engine saved
from one mesh restores onto a DIFFERENT (smaller) mesh serving identical
results. Fast cases run in-process on a 1-device mesh (the default mesh
shape); the multi-shard cases run in a subprocess with 8 forced host
devices, exactly like tests/test_multidevice.py.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FCVIConfig, build, fcvi
from repro.data.synthetic import CorpusSpec, make_corpus, sample_queries
from repro.launch.mesh import make_mesh
from repro.serve.engine import EngineConfig, FCVIEngine

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_in_subprocess(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.fixture(scope="module")
def data():
    spec = CorpusSpec(n=1000, d=64, n_categories=5, n_numeric=3, seed=2)
    corpus = make_corpus(spec)
    q, fq = sample_queries(corpus, 5, seed=3)
    return corpus, np.asarray(q), np.asarray(fq)


def _engines(corpus, backend, use_pallas, mesh, **eng_kw):
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend=backend, nlist=16,
                     nprobe=4, use_pallas=use_pallas)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    kw = dict(k=5, batch_size=16, compact_threshold=256)
    kw.update(eng_kw)
    e0 = FCVIEngine(idx, EngineConfig(**kw))
    e1 = FCVIEngine(idx, EngineConfig(**kw), mesh=mesh)
    return e0, e1


def _assert_identical(a, b):
    (s0, i0), (s1, i1) = a, b
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


@pytest.mark.parametrize("backend", ["flat", "ivf"])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_one_device_mesh_identical_with_delta(data, backend, use_pallas):
    """A 1-device mesh (the default mesh shape) must be bit-identical to the
    meshless engine — including the sharded delta merge path."""
    corpus, q, fq = data
    mesh = make_mesh((1, 1), ("data", "model"))
    e0, e1 = _engines(corpus, backend, use_pallas, mesh)
    r = np.random.default_rng(0)
    nv = r.normal(size=(20, corpus.spec.d)).astype(np.float32)
    nf = corpus.filters[:20].copy()
    e0.insert(nv, nf)
    e1.insert(nv, nf)
    _assert_identical(e0.search(q, fq), e1.search(q, fq))


@pytest.mark.parametrize("use_pallas", [False, True])
def test_pq_backend_serves_on_mesh(data, use_pallas):
    """PQ is mesh-servable: replicated codebook LUT terms, row-sharded
    codes. A 1-device mesh must be bit-identical to the meshless engine."""
    corpus, q, fq = data
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend="pq", pq_m=8,
                     pq_ksub=32, pq_coarse=8, use_pallas=use_pallas)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    kw = dict(k=5, batch_size=16, compact_threshold=256)
    e0 = FCVIEngine(idx, EngineConfig(**kw))
    e1 = FCVIEngine(idx, EngineConfig(**kw),
                    mesh=make_mesh((1, 1), ("data", "model")))
    _assert_identical(e0.search(q, fq), e1.search(q, fq))


def test_save_restore_roundtrip_meshless(data, tmp_path):
    """build -> insert -> save -> restore -> serve must be identical, with
    the pending delta rows surviving the checkpoint."""
    corpus, q, fq = data
    e0, _ = _engines(corpus, "ivf", False, make_mesh((1, 1), ("data", "model")))
    r = np.random.default_rng(1)
    e0.insert(r.normal(size=(12, corpus.spec.d)).astype(np.float32),
              corpus.filters[:12].copy())
    want = e0.search(q, fq)
    e0.save(str(tmp_path), step=3)
    er = FCVIEngine.restore(str(tmp_path))
    assert er.delta_size() == 12
    assert er.index.config == e0.index.config
    _assert_identical(want, er.search(q, fq))


def test_index_state_roundtrip_all_backends(data):
    """index_state/index_from_state reproduce identical query results for
    every backend (incl. rematerialised IVF slabs and PQ LUT terms)."""
    corpus, q, fq = data
    qj, fj = jnp.asarray(q), jnp.asarray(fq)
    for backend in ("flat", "ivf", "pq"):
        cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend=backend, nlist=16,
                         nprobe=4, pq_m=8, pq_ksub=32, pq_coarse=8)
        idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters),
                    cfg)
        idx2 = fcvi.index_from_state(cfg, fcvi.index_state(idx))
        _assert_identical(fcvi.query(idx, qj, fj, 7),
                          fcvi.query(idx2, qj, fj, 7))


_SUBPROCESS_PRELUDE = """
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import FCVIConfig, build
    from repro.data.synthetic import CorpusSpec, make_corpus, sample_queries
    from repro.launch.mesh import make_mesh
    from repro.serve.engine import EngineConfig, FCVIEngine

    assert len(jax.devices()) == 8
    spec = CorpusSpec(n=1000, d=64, n_categories=5, n_numeric=3, seed=2)
    corpus = make_corpus(spec)
    q, fq = sample_queries(corpus, 5, seed=3)
    q, fq = np.asarray(q), np.asarray(fq)

    def engines(backend, use_pallas, mesh, placement="contiguous"):
        cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend=backend,
                         nlist=16, nprobe=4, pq_m=8, pq_ksub=32,
                         pq_coarse=8, use_pallas=use_pallas)
        idx = build(jnp.asarray(corpus.vectors),
                    jnp.asarray(corpus.filters), cfg)
        ek = dict(k=5, batch_size=16, compact_threshold=256)
        return (FCVIEngine(idx, EngineConfig(**ek)),
                FCVIEngine(idx, EngineConfig(**ek), mesh=mesh,
                           placement=placement))

    def check(a, b, tag):
        (s0, i0), (s1, i1) = a, b
        assert (np.asarray(i0) == np.asarray(i1)).all(), tag
        assert (np.asarray(s0) == np.asarray(s1)).all(), tag
"""


@pytest.mark.slow
def test_eight_device_mesh_parity():
    """Acceptance: top-k ids and scores on a forced 8-device host mesh match
    the single-device engine exactly — flat, IVF and PQ, kernels on and off,
    with a live delta buffer."""
    run_in_subprocess(_SUBPROCESS_PRELUDE + """
    mesh = make_mesh((8, 1), ("data", "model"))
    r = np.random.default_rng(0)
    nv = r.normal(size=(20, spec.d)).astype(np.float32)
    nf = corpus.filters[:20].copy()
    for backend in ("flat", "ivf", "pq"):
        for use_pallas in (False, True):
            e0, e1 = engines(backend, use_pallas, mesh)
            assert e1._sharded.n_shards == 8
            check(e0.search(q, fq), e1.search(q, fq),
                  (backend, use_pallas, "no-delta"))
            e0.insert(nv, nf); e1.insert(nv, nf)
            e0._cache.clear(); e1._cache.clear()
            check(e0.search(q, fq), e1.search(q, fq),
                  (backend, use_pallas, "delta"))
    print("8-device parity OK")
    """)


@pytest.mark.slow
def test_cluster_placement_parity_and_multi_axis_mesh():
    """Filter-centric placements (cluster row packing, balanced list packing)
    and a 4x2 two-axis merge tree must stay exact."""
    run_in_subprocess(_SUBPROCESS_PRELUDE + """
    mesh = make_mesh((4, 2), ("data", "model"))
    rules_mesh = make_mesh((8, 1), ("data", "model"))
    for backend in ("flat", "ivf"):
        e0, e1 = engines(backend, False, rules_mesh, placement="cluster")
        check(e0.search(q, fq), e1.search(q, fq), (backend, "cluster"))
    # two-axis corpus sharding: corpus rule resolves to ("data",) on this
    # mesh; override to shard over both axes and merge per axis
    from repro.distributed.sharding import AxisRules
    rules = AxisRules(mesh, {"corpus": ("data", "model"),
                             "ivf_lists": ("data", "model")})
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    ek = dict(k=5, batch_size=16)
    e0 = FCVIEngine(idx, EngineConfig(**ek))
    e1 = FCVIEngine(idx, EngineConfig(**ek), mesh=mesh, rules=rules)
    assert e1._sharded.n_shards == 8 and len(e1._sharded.axes) == 2
    check(e0.search(q, fq), e1.search(q, fq), "two-axis")
    print("placement + multi-axis parity OK")
    """)


@pytest.mark.slow
def test_elastic_restore_onto_smaller_mesh():
    """Acceptance: save from an 8-device mesh, restore onto a 2-device mesh
    (and meshless), serve identical results — the elastic-restart path."""
    run_in_subprocess(_SUBPROCESS_PRELUDE + """
    import tempfile
    mesh8 = make_mesh((8, 1), ("data", "model"))
    mesh2 = make_mesh((2, 1), ("data", "model"))
    for backend in ("flat", "ivf"):
        _, e8 = engines(backend, False, mesh8)
        r = np.random.default_rng(0)
        e8.insert(r.normal(size=(20, spec.d)).astype(np.float32),
                  corpus.filters[:20].copy())
        want = e8.search(q, fq)
        tmp = tempfile.mkdtemp()
        e8.save(tmp, step=1)
        er2 = FCVIEngine.restore(tmp, mesh=mesh2)
        assert er2.delta_size() == 20 and er2._sharded.n_shards == 2
        check(want, er2.search(q, fq), (backend, "restore-2dev"))
        er0 = FCVIEngine.restore(tmp)
        check(want, er0.search(q, fq), (backend, "restore-meshless"))
    print("elastic restore OK")
    """)


@pytest.mark.slow
def test_sharded_step_does_not_retrace():
    """The shard_map step must trace once per (shape, delta, k') signature,
    like the single-device step — steady-state batches may not recompile."""
    run_in_subprocess(_SUBPROCESS_PRELUDE + """
    from repro.serve import engine as engine_mod
    mesh = make_mesh((8, 1), ("data", "model"))
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=8.0)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    eng = FCVIEngine(idx, EngineConfig(k=5, batch_size=16,
                                       compact_threshold=512,
                                       escalate_margin=1e9), mesh=mesh)
    r = np.random.default_rng(4)
    eng.insert(r.normal(size=(16, spec.d)).astype(np.float32),
               corpus.filters[:16].copy())
    qq, ff = sample_queries(corpus, 16, seed=9)
    eng.search(qq, ff)
    warm = engine_mod.trace_count()
    for seed in (10, 11, 12):
        qq, ff = sample_queries(corpus, 16, seed=seed)
        eng._cache.clear()
        eng.search(qq, ff)
    assert engine_mod.trace_count() == warm, "sharded step retraced"
    print("no retracing OK")
    """)
