"""Predicate-filtered search: the filter algebra + selectivity-aware planner.

Builds an FCVI index over a synthetic product catalog whose rows carry RAW
attribute columns (price, stock, category one-hots), then serves composable
predicates through ``engine.search(q, filter=...)``:

  * ``F.range / F.eq / F.isin`` combined with ``&`` into conjunctions;
  * the planner picks a physical plan per query from per-column selectivity
    statistics — psi ``fold`` for broad single-attribute predicates,
    in-kernel ``mask`` as the safe default, ``routed`` shard/list pruning
    for selective ones;
  * every plan is EXACT: forcing each capable plan returns bit-identical
    scores and ids, and a mesh-sharded engine matches the meshless one;
  * a predicate matching nothing returns certified-empty ``(-inf, -1)``
    rows instead of garbage.

Runs anywhere (no TPU needed). To exercise the sharded filtered step:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/filtered_predicates.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FCVIConfig, build
from repro.core.filters import F, compile_predicate
from repro.launch.mesh import make_host_mesh
from repro.serve.engine import EngineConfig, FCVIEngine

N, D = 4096, 32
NAMES = ("price", "stock", "cat_a", "cat_b")


def main():
    r = np.random.default_rng(0)
    vectors = r.normal(size=(N, D)).astype(np.float32)
    # raw attribute columns: price in [0, 100), stock in [0, 1), two
    # category one-hots (the table feeds both predicate evaluation and the
    # fold plan's psi target, so it has m = 4 columns like the index filters)
    cat = r.integers(0, 2, N)
    attrs = np.stack([r.uniform(0, 100, N), r.uniform(0, 1, N),
                      (cat == 0).astype(np.float32),
                      (cat == 1).astype(np.float32)], axis=1).astype(np.float32)

    index = build(jnp.asarray(vectors), jnp.asarray(attrs),
                  FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend="ivf",
                             nlist=16, nprobe=8))
    engine = FCVIEngine(index, EngineConfig(k=5, batch_size=32),
                        attributes=attrs, attr_names=NAMES)
    q = r.normal(size=(16, D)).astype(np.float32)

    # the planner maps selectivity bands to plans; predicate state is data,
    # so varying the bounds below never retraces the serving step
    preds = [
        ("broad price band", F.range("price", 5.0, 95.0)),
        ("mid conjunction", F.range("price", 20.0, 60.0) & F.eq("cat_a", 1.0)),
        ("narrow corner", F.range("price", 0.0, 3.0) & F.range("stock", 0.0, 0.4)),
    ]
    for label, pred in preds:
        cp = compile_predicate(pred, NAMES)
        plan = engine.planner.choose(cp)
        sel = engine.planner.selectivity(cp)
        scores, ids = engine.search(q, filter=pred)
        n_hits = int((ids[0] >= 0).sum())
        print(f"{label:18s} est_sel={sel:0.3f} plan={plan:6s} "
              f"top-{n_hits} ids={ids[0][:3].tolist()}")
        # exactness: every row returned satisfies the predicate
        live = ids[ids >= 0]
        assert bool(cp.eval_np(attrs[live]).all())

    # the plan is a pure performance knob — force each capable plan and get
    # bit-identical results
    pred = F.range("price", 0.0, 10.0)
    base = engine.search(q, filter=pred)
    for plan in ("mask", "routed"):
        s, i = engine.search(q, filter=pred, plan=plan)
        assert (s == base[0]).all() and (i == base[1]).all()
    print("forced mask == routed == planner choice: OK")

    # the fold plan (the paper's psi transform carrying the predicate) needs
    # the flat fp32 scan: on a flat engine the broad band folds instead
    flat_idx = build(jnp.asarray(vectors), jnp.asarray(attrs),
                     FCVIConfig(alpha=1.0, lam=0.6, c=8.0, backend="flat"))
    flat_eng = FCVIEngine(flat_idx, EngineConfig(k=5, batch_size=32),
                          attributes=attrs, attr_names=NAMES)
    cp = compile_predicate(preds[0][1], NAMES)
    sf, if_ = flat_eng.search(q, filter=preds[0][1])
    sm, im = flat_eng.search(q, filter=preds[0][1], plan="mask")
    assert flat_eng.planner.choose(cp) == "fold"
    assert (sf == sm).all() and (if_ == im).all()
    print(f"flat engine: broad band folds (plan="
          f"{flat_eng.planner.choose(cp)}), fold == mask bitwise: OK")

    # zero-match predicates return certified-empty rows, not id-0 garbage
    s, i = engine.search(q, filter=F.range("price", 1000.0, 2000.0))
    assert (i == -1).all() and np.isneginf(s).all()
    print("zero-match predicate -> certified empty: OK")

    # mesh-sharded serving answers the same predicates bit-identically
    # (per-shard lax.cond skips shards with no eligible rows on routed plans)
    mesh = make_host_mesh()
    sharded = FCVIEngine(index, EngineConfig(k=5, batch_size=32),
                         mesh=mesh, attributes=attrs, attr_names=NAMES)
    for _, pred in preds:
        s0, i0 = engine.search(q, filter=pred)
        s1, i1 = sharded.search(q, filter=pred)
        assert (s0 == s1).all() and (i0 == i1).all()
    print(f"sharded ({len(jax.devices())} device(s)) == meshless: OK")

    # live inserts are predicate-checked against their insert attributes
    engine.insert(vectors[:8] + 0.01, attrs[:8])
    engine.search(q, filter=preds[0][1])
    st = engine.stats
    print(f"stats: {st.filtered_queries} filtered queries, plans "
          f"fold={st.plan_fold} mask={st.plan_mask} routed={st.plan_routed}, "
          f"{st.filtered_fallbacks} fold fallbacks")


if __name__ == "__main__":
    main()
