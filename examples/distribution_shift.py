"""Distribution-shift stability demo (paper §6.3 / Table 2).

Shows FCVI's recall holding steady under filter- and vector-distribution
shifts WITHOUT rebuilding the index, while post-filtering degrades.

    PYTHONPATH=src python examples/distribution_shift.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (FCVIConfig, build, query, ground_truth_combined,
                        recall_at_k)
from repro.data.synthetic import (CorpusSpec, make_corpus, sample_queries,
                                  shift_filter_distribution,
                                  shift_vector_distribution,
                                  shifted_query_pattern)


def fcvi_recall(idx, q, fq, k=10):
    qj, fj = jnp.asarray(q), jnp.asarray(fq)
    _, ids = query(idx, qj, fj, k)
    qn, fqn = idx.transform.normalize(qj, fj)
    _, ref = ground_truth_combined(idx.vectors_n, idx.filters_n, qn, fqn, k,
                                   idx.config.lam)
    return float(recall_at_k(ids, ref))


def main():
    spec = CorpusSpec(n=12000, d=64, n_categories=6, n_numeric=2, seed=10)
    corpus = make_corpus(spec)
    idx = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters),
                FCVIConfig(alpha=1.0, lam=0.6, c=16.0))
    q, fq = sample_queries(corpus, 48, seed=11)
    base = fcvi_recall(idx, q, fq)
    print(f"baseline recall@10:            {base:.3f}")

    sh = shift_filter_distribution(corpus)
    q2, fq2 = sample_queries(sh, 48, seed=12)
    print(f"after FILTER-dist shift:       {fcvi_recall(idx, q2, fq2):.3f}  "
          "(index NOT rebuilt)")

    sv = shift_vector_distribution(corpus)
    q3, fq3 = sample_queries(sv, 48, seed=13)
    print(f"after VECTOR-dist shift:       {fcvi_recall(idx, q3, fq3):.3f}")

    q4, fq4 = shifted_query_pattern(corpus, 48)
    print(f"under shifted QUERY pattern:   {fcvi_recall(idx, q4, fq4):.3f}")
    print("\n(see benchmarks/table2.py for the full latency+recall protocol "
          "with pre-/post-filter baselines)")


if __name__ == "__main__":
    main()
