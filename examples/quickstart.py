"""Quickstart: build an FCVI index, run filtered queries, compare baselines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (FCVIConfig, build, query, ground_truth_combined,
                        recall_at_k, BoxPredicate, post_filter_search,
                        ground_truth_filtered)
from repro.data.synthetic import CorpusSpec, make_corpus, sample_queries
from repro.index import flat as flat_mod


def main():
    # 1. a corpus of vectors with filter attributes (e.g. product embeddings
    #    with [category-onehot..., price, rating])
    spec = CorpusSpec(n=20000, d=128, n_categories=6, n_numeric=2, seed=0)
    corpus = make_corpus(spec)
    print(f"corpus: {spec.n} vectors, d={spec.d}, m={spec.m} filter dims")

    # 2. offline indexing (Alg. 1): psi-transform + any ANN backend
    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=16.0, backend="flat")
    index = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)

    # 3. online filtered queries: (query vector, filter target)
    q, fq = sample_queries(corpus, 32, seed=1)
    scores, ids = query(index, jnp.asarray(q), jnp.asarray(fq), k=10)

    qn, fqn = index.transform.normalize(jnp.asarray(q), jnp.asarray(fq))
    _, ref = ground_truth_combined(index.vectors_n, index.filters_n,
                                   qn, fqn, 10, cfg.lam)
    print(f"FCVI recall@10 vs combined-score oracle: "
          f"{float(recall_at_k(ids, ref)):.3f}")

    # 4. compare with post-filtering under a selective CATEGORY predicate
    #    (narrow numeric ranges are the multi-probe case — see
    #    examples/multiprobe_range_filters.py)
    rare = int(np.bincount(corpus.cat_labels,
                           minlength=spec.n_categories).argmin())
    lo = np.full(spec.m, -np.inf, np.float32)
    hi = np.full(spec.m, np.inf, np.float32)
    lo[rare], hi[rare] = 0.5, 1.5                    # category == rare
    pred = BoxPredicate(low=jnp.asarray(lo), high=jnp.asarray(hi))
    sel = float(np.asarray(pred.mask(jnp.asarray(corpus.filters))).mean())
    print(f"selective category predicate: {sel:.1%} of corpus")
    raw = flat_mod.build(jnp.asarray(corpus.vectors))
    _, post_ids = post_filter_search(raw, jnp.asarray(corpus.filters),
                                     jnp.asarray(q), pred, 10, oversample=5)
    _, pref = ground_truth_filtered(jnp.asarray(corpus.vectors),
                                    jnp.asarray(corpus.filters),
                                    jnp.asarray(q), pred, 10)
    fq1 = np.asarray(pred.to_filter_query(jnp.asarray(corpus.filters)))
    fq_pred = np.broadcast_to(fq1, (32, spec.m)).copy()
    cfg2 = FCVIConfig(alpha=2.0, lam=0.4, c=16.0)
    idx2 = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg2)
    _, fids = query(idx2, jnp.asarray(q), jnp.asarray(fq_pred), 10)
    print(f"selective predicate: post-filter recall="
          f"{float(recall_at_k(post_ids, pref)):.3f}  "
          f"FCVI recall={float(recall_at_k(fids, pref)):.3f}")


if __name__ == "__main__":
    main()
