"""End-to-end serving driver: LM-embedded documents -> mesh-sharded FCVI
engine with filter-routed serving -> batched filtered queries -> checkpoint
save/restore.

A reduced gemma3-family model embeds token sequences (mean-pooled final
hidden states); documents carry filter attributes (topic one-hot + recency);
the FCVIEngine serves batched requests over whatever device mesh this host
has — with filter-centric (cluster) placement and ``routing="routed"``,
shards holding none of a query's psi-clusters skip their scan, and any query
the router cannot certify is transparently re-run dense, so routed results
are identical to dense ones. The engine state then round-trips through a
checkpoint (``engine.save`` -> ``FCVIEngine.restore``), the elastic-restart
path.

Runs anywhere (no TPU needed); with one device the mesh/routing knobs are
exercised as no-ops. To see real routing, force several host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_filtered_search.py
"""
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import FCVIConfig, build
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.serve.engine import EngineConfig, FCVIEngine

N_DOCS, SEQ, N_TOPICS = 2048, 32, 6


def embed_docs(params, cfg, tokens):
    """Mean-pooled final hidden state as the document embedding."""
    h = M.forward_hidden(params, cfg, {"tokens": tokens})
    return np.asarray(jnp.mean(h.astype(jnp.float32), axis=1))


def main():
    rng = jax.random.PRNGKey(0)
    cfg = reduced(get_config("gemma3-1b"))
    params = M.init_params(rng, cfg)
    print(f"embedder: reduced {cfg.name} ({M.param_count(params):,} params)")

    # synthetic "documents": token sequences whose leading token block encodes
    # the topic, so embeddings cluster by topic
    r = np.random.default_rng(0)
    topics = r.integers(0, N_TOPICS, N_DOCS)
    tokens = r.integers(0, cfg.vocab_size, (N_DOCS, SEQ)).astype(np.int32)
    tokens[:, :8] = (topics[:, None] * 17 + np.arange(8)) % cfg.vocab_size

    t0 = time.perf_counter()
    embs = np.concatenate([
        embed_docs(params, cfg, jnp.asarray(tokens[i:i + 256]))
        for i in range(0, N_DOCS, 256)])
    print(f"embedded {N_DOCS} docs in {time.perf_counter()-t0:.1f}s "
          f"-> d={embs.shape[1]}")

    onehot = np.zeros((N_DOCS, N_TOPICS), np.float32)
    onehot[np.arange(N_DOCS), topics] = 1.0
    recency = r.uniform(0, 1, (N_DOCS, 2)).astype(np.float32)
    filters = np.concatenate([onehot, recency], axis=1)

    # offline build: psi-transform (strong filter fold -> filtered queries
    # are geometrically local) + flat backend over the transformed corpus
    index = build(jnp.asarray(embs), jnp.asarray(filters),
                  FCVIConfig(alpha=2.0, lam=0.5, c=8.0))

    # mesh-sharded, filter-routed serving: cluster placement packs whole
    # psi-clusters per shard; routing="routed" skips shards the router does
    # not activate for a batch (exactness kept by the dense fallback)
    mesh = make_host_mesh()
    print(f"mesh: {len(jax.devices())} device(s), "
          f"placement=cluster routing=routed")
    engine = FCVIEngine(index, EngineConfig(k=5, batch_size=32),
                        mesh=mesh, placement="cluster", routing="routed")

    # batched serving: queries = docs' own embeddings + topic filters —
    # selective filtered traffic, exactly what routing exploits
    q_ids = r.integers(0, N_DOCS, 128)
    queries = embs[q_ids] + 0.05 * r.normal(
        size=(128, embs.shape[1])).astype(np.float32)
    fq = filters[q_ids]
    t0 = time.perf_counter()
    scores, ids = engine.search(queries, fq)
    dt = time.perf_counter() - t0
    topic_match = (topics[ids[:, 0]] == topics[q_ids]).mean()
    st = engine.stats
    print(f"served 128 queries in {dt*1e3:.0f}ms ({128/dt:.0f} qps), "
          f"top-1 topic match: {topic_match:.2%}")
    print(f"router: {st.shard_skip_rate:.0%} of shard scans skipped, "
          f"{st.router_fallbacks} dense fallbacks, "
          f"{st.escalations} escalations")

    # the routing knob never changes results: a dense engine over the same
    # index returns bit-identical scores and ids
    dense = FCVIEngine(index, EngineConfig(k=5, batch_size=32),
                       mesh=mesh, placement="cluster", routing="dense")
    ds, di = dense.search(queries, fq)
    assert (ds == scores).all() and (di == ids).all()
    print("routed == dense: OK")

    # live inserts through the delta buffer
    engine.insert(embs[:64] + 0.01, filters[:64])
    engine.search(queries[:16], fq[:16])
    print(f"after insert: delta={engine.delta_size()} rows, "
          f"stats: {st.queries} queries, {st.cache_hits} cache hits")

    # checkpoint lifecycle: save (router tables included) -> restore onto
    # this host's mesh -> identical results, identical routing
    with tempfile.TemporaryDirectory() as ckpt_dir:
        engine.save(ckpt_dir, step=1)
        restored = FCVIEngine.restore(ckpt_dir, mesh=mesh)
        engine._cache.clear()
        s0, i0 = engine.search(queries[:32], fq[:32])
        s1, i1 = restored.search(queries[:32], fq[:32])
        assert (s0 == s1).all() and (i0 == i1).all()
        print(f"checkpoint restore (routing={restored._routing!r}): "
              f"identical results OK")


if __name__ == "__main__":
    main()
