"""End-to-end serving driver: LM-embedded documents -> FCVI engine -> batched
filtered queries (the paper-kind end-to-end example, deliverable b).

A reduced gemma3-family model embeds token sequences (mean-pooled final
hidden states); documents carry filter attributes (topic one-hot + recency);
the FCVIEngine serves batched requests with caching / adaptive k' /
escalation, plus live inserts with delta-buffer compaction.

    PYTHONPATH=src python examples/serve_filtered_search.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import FCVIConfig, build
from repro.models import model as M
from repro.serve.engine import EngineConfig, FCVIEngine

N_DOCS, SEQ, N_TOPICS = 2048, 32, 6


def embed_docs(params, cfg, tokens):
    """Mean-pooled final hidden state as the document embedding."""
    h = M.forward_hidden(params, cfg, {"tokens": tokens})
    return np.asarray(jnp.mean(h.astype(jnp.float32), axis=1))


def main():
    rng = jax.random.PRNGKey(0)
    cfg = reduced(get_config("gemma3-1b"))
    params = M.init_params(rng, cfg)
    print(f"embedder: reduced {cfg.name} ({M.param_count(params):,} params)")

    # synthetic "documents": token sequences whose leading token block encodes
    # the topic, so embeddings cluster by topic
    r = np.random.default_rng(0)
    topics = r.integers(0, N_TOPICS, N_DOCS)
    tokens = r.integers(0, cfg.vocab_size, (N_DOCS, SEQ)).astype(np.int32)
    tokens[:, :8] = (topics[:, None] * 17 + np.arange(8)) % cfg.vocab_size

    t0 = time.perf_counter()
    embs = np.concatenate([
        embed_docs(params, cfg, jnp.asarray(tokens[i:i + 256]))
        for i in range(0, N_DOCS, 256)])
    print(f"embedded {N_DOCS} docs in {time.perf_counter()-t0:.1f}s "
          f"-> d={embs.shape[1]}")

    onehot = np.zeros((N_DOCS, N_TOPICS), np.float32)
    onehot[np.arange(N_DOCS), topics] = 1.0
    recency = r.uniform(0, 1, (N_DOCS, 2)).astype(np.float32)
    filters = np.concatenate([onehot, recency], axis=1)

    index = build(jnp.asarray(embs), jnp.asarray(filters),
                  FCVIConfig(alpha=1.5, lam=0.5, c=8.0))
    engine = FCVIEngine(index, EngineConfig(k=5, batch_size=32))

    # batched serving: queries = docs' own embeddings + topic filters
    q_ids = r.integers(0, N_DOCS, 128)
    queries = embs[q_ids] + 0.05 * r.normal(size=(128, embs.shape[1])).astype(np.float32)
    fq = filters[q_ids]
    t0 = time.perf_counter()
    scores, ids = engine.search(queries, fq)
    dt = time.perf_counter() - t0
    topic_match = (topics[ids[:, 0]] == topics[q_ids]).mean()
    print(f"served 128 queries in {dt*1e3:.0f}ms "
          f"({128/dt:.0f} qps), top-1 topic match: {topic_match:.2%}")

    # live inserts through the delta buffer
    engine.insert(embs[:64] + 0.01, filters[:64])
    scores, ids = engine.search(queries[:16], fq[:16])
    print(f"after insert: delta={engine.delta_size()} rows, "
          f"stats: {engine.stats.queries} queries, "
          f"{engine.stats.cache_hits} cache hits, "
          f"{engine.stats.escalations} escalations")


if __name__ == "__main__":
    main()
