"""Range-predicate multi-probe demo (paper §4.3).

A price-range query ("similar items between $50-$100") becomes r transformed
probes along the range; candidates are merged, deduped and re-scored against
the NEAREST probe.

    PYTHONPATH=src python examples/multiprobe_range_filters.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (FCVIConfig, build, multi_probe_query, BoxPredicate,
                        ground_truth_filtered, recall_at_k)
from repro.data.synthetic import CorpusSpec, make_corpus, sample_queries


def main():
    spec = CorpusSpec(n=12000, d=64, n_categories=4, n_numeric=4, seed=21)
    corpus = make_corpus(spec)
    v, f = jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters)
    idx = build(v, f, FCVIConfig(alpha=2.0, lam=0.4, c=16.0))
    q, _ = sample_queries(corpus, 32, seed=22)
    qj = jnp.asarray(q)

    # range predicate on the 'price' attribute (first numeric dim)
    m = spec.m
    lo = np.full(m, -np.inf, np.float32)
    hi = np.full(m, np.inf, np.float32)
    price_dim = spec.n_categories
    lo[price_dim], hi[price_dim] = 0.3, 0.7
    pred = BoxPredicate(low=jnp.asarray(lo), high=jnp.asarray(hi))
    sel = float(np.asarray(pred.mask(f)).mean())
    print(f"range predicate selectivity: {sel:.1%}")
    print("(broad ranges sit in pre-filter territory — UNIFY-style routing"
          " in repro.core.baselines picks strategies by range width; this"
          " example shows the multi-probe candidate+verify flow)")

    _, ref = ground_truth_filtered(v, f, qj, pred, 10)
    for r in (1, 2, 4, 8):
        probes = pred.probes(r)                        # (r, m)
        pb = jnp.broadcast_to(probes[None], (32, r, m))
        # production pattern: FCVI multi-probe generates candidates, the
        # exact predicate verifies, then final top-k (paper §4.3 + §3.3)
        cscores, cids = multi_probe_query(idx, qj, pb, 200)
        ok = pred.mask(f[cids])
        # rank verified candidates by exact vector distance (the oracle's
        # metric) — FCVI generated them, the predicate verified them
        cand_v = v[cids]                               # (b, 200, d)
        d2 = jnp.sum((cand_v - qj[:, None, :]) ** 2, -1)
        vscores = jnp.where(ok, -d2, -jnp.inf)
        _, pos = jax.lax.top_k(vscores, 10)
        ids = jnp.take_along_axis(cids, pos, axis=-1)
        in_range = float(np.asarray(pred.mask(f[ids])).mean())
        rec = float(recall_at_k(ids, ref))
        print(f"r={r} probes + verify: recall@10={rec:.3f}, "
              f"results in range={in_range:.1%}")


if __name__ == "__main__":
    main()
