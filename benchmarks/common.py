"""Shared benchmark utilities: timing, corpus setup, method registry."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (FCVIConfig, build, query, ground_truth_combined,
                        recall_at_k, BoxPredicate, post_filter_search,
                        pre_filter_search, build_hybrid, hybrid_search,
                        ground_truth_filtered)
from repro.data.synthetic import CorpusSpec, make_corpus, sample_queries
from repro.index import flat as flat_mod


def timeit(fn, *args, warmup=1, iters=3):
    """Median wall time (s) with jit warmup; blocks on results."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "size"))


def default_world(n=20000, d=64, n_queries=64, seed=0):
    spec = CorpusSpec(n=n, d=d, n_categories=6, n_numeric=2, seed=seed)
    corpus = make_corpus(spec)
    q, fq = sample_queries(corpus, n_queries, seed=seed + 1)
    return corpus, np.asarray(q), np.asarray(fq)


def moderate_predicate(corpus):
    """~25-40% selectivity numeric range predicate."""
    spec = corpus.spec
    lo = np.full(spec.m, -np.inf, np.float32)
    hi = np.full(spec.m, np.inf, np.float32)
    lo[-1], hi[-1] = 0.25, 0.6
    return BoxPredicate(low=jnp.asarray(lo), high=jnp.asarray(hi))


def fcvi_recall(index, q, fq, k):
    _, ids = query(index, jnp.asarray(q), jnp.asarray(fq), k)
    qn, fqn = index.transform.normalize(jnp.asarray(q), jnp.asarray(fq))
    _, ref = ground_truth_combined(index.vectors_n, index.filters_n, qn, fqn,
                                   k, index.config.lam)
    return float(recall_at_k(ids, ref))
