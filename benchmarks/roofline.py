"""Roofline table generator — reads the dry-run artifacts (deliverable g).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--mesh pod16x16]
Writes artifacts/roofline_table.md and prints a summary.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(mesh: str):
    rows = []
    for f in sorted(glob.glob(os.path.join(ART, f"*_{mesh}.json"))):
        rows.append(json.load(open(f)))
    return rows


def fmt_table(rows):
    hdr = ("| arch | shape | status | compute_s | memory_s | collective_s | "
           "dominant | useful FLOPs | peak/dev GiB (raw / TPU-proj) |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']}: "
                       f"{r.get('reason', r.get('error',''))[:40]} |  |  |  |  |  |  |")
            continue
        t = r["roofline"]
        ma = r["memory_analysis"]
        out.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {t['compute_s']:.3f} | {t['memory_s']:.3f} "
            f"| {t['collective_s']:.3f} | **{t['dominant']}** "
            f"| {r['useful_flops_fraction']:.1%} "
            f"| {ma['peak_estimate_bytes']/2**30:.1f} / "
            f"{ma.get('projected_tpu_peak_bytes', 0)/2**30:.1f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    rows = load(args.mesh)
    if not rows:
        print(f"no artifacts for mesh {args.mesh}; run repro.launch.dryrun first")
        return
    table = fmt_table(rows)
    out = os.path.join(os.path.dirname(ART), f"roofline_table_{args.mesh}.md")
    with open(out, "w") as f:
        f.write(f"# Roofline — {args.mesh} (per-device terms, v5e constants)\n\n")
        f.write(table + "\n")
    print(table)
    print(f"\nwritten: {out}")


if __name__ == "__main__":
    main()
