"""Roofline table generator — dry-run artifacts + the storage-dtype ladder.

Two tables:

1. The dry-run artifact table (deliverable g): per-arch compute/memory/
   collective roofline terms read from ``artifacts/dryrun/*_<mesh>.json``.

2. The serving-scan storage ladder: an analytic roofline of the engine's
   candidate-generation scan at each storage rung (fp32 / bf16 / int8 codes
   + per-row fp32 scales / PQ codes). The scan streams the slab once per
   batch and does 2*d FLOPs per row per query, so its arithmetic intensity
   scales with BATCH / bytes-per-row — quantization moves the scan toward
   the compute roof at fixed batch, or equivalently lowers the batch size
   at which it stops being HBM-bound. Uses the v5e constants from
   ``repro.launch.hlo_analysis`` (197 TFLOP/s, 819 GB/s).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--mesh pod16x16]
           [--d 64] [--batch 64 256] [--pq-m 8]
Writes artifacts/roofline_table_<mesh>.md (artifact table, when artifacts
exist) and artifacts/roofline_storage_ladder.md; prints both.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.launch.hlo_analysis import HBM_BW, PEAK_FLOPS

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(mesh: str):
    rows = []
    for f in sorted(glob.glob(os.path.join(ART, f"*_{mesh}.json"))):
        rows.append(json.load(open(f)))
    return rows


def fmt_table(rows):
    hdr = ("| arch | shape | status | compute_s | memory_s | collective_s | "
           "dominant | useful FLOPs | peak/dev GiB (raw / TPU-proj) |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']}: "
                       f"{r.get('reason', r.get('error',''))[:40]} |  |  |  |  |  |  |")
            continue
        t = r["roofline"]
        ma = r["memory_analysis"]
        out.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {t['compute_s']:.3f} | {t['memory_s']:.3f} "
            f"| {t['collective_s']:.3f} | **{t['dominant']}** "
            f"| {r['useful_flops_fraction']:.1%} "
            f"| {ma['peak_estimate_bytes']/2**30:.1f} / "
            f"{ma.get('projected_tpu_peak_bytes', 0)/2**30:.1f} |")
    return "\n".join(out)


def storage_rungs(d: int, pq_m: int):
    """Bytes streamed per corpus row at each storage rung of the scan.

    Every rung also streams the row's fp32 squared norm (4 B); the int8
    rung adds its per-row fp32 dequant scale; PQ streams code bytes only
    (its LUT build is O(ksub*d) per query, amortised over n rows and
    ignored here).
    """
    return [
        ("float32", 4 * d + 4),
        ("bfloat16", 2 * d + 4),
        ("int8", d + 4 + 4),
        (f"pq (M={pq_m})", pq_m),
    ]


def ladder_rows(d: int, batches, pq_m: int):
    """Analytic roofline of the batched slab scan per storage rung.

    Per corpus row and batch of b queries the scan does ``2*d*b`` FLOPs
    (fused multiply-add dot against each query) over ``bytes_row`` streamed
    bytes, so arithmetic intensity AI = 2*d*b / bytes_row FLOP/B. The rung
    is HBM-bound while AI < PEAK_FLOPS / HBM_BW (~240 FLOP/B on v5e).
    """
    ridge = PEAK_FLOPS / HBM_BW
    rows = []
    for name, bytes_row in storage_rungs(d, pq_m):
        flops_row = 2.0 * d            # per query in the batch
        for b in batches:
            ai = flops_row * b / bytes_row
            bound = "memory" if ai < ridge else "compute"
            # time per (row, batch) under the binding roof, normalised to
            # rows/s per device at this batch size
            t_mem = bytes_row / HBM_BW
            t_cmp = flops_row * b / PEAK_FLOPS
            rows_per_s = 1.0 / max(t_mem, t_cmp)
            rows.append(dict(storage=name, bytes_per_row=bytes_row, batch=b,
                             arithmetic_intensity=ai, bound=bound,
                             grows_per_s=rows_per_s / 1e9))
    return rows


def fmt_ladder(rows):
    hdr = ("| storage | bytes/row | batch | AI (FLOP/B) | bound | "
           "roof Grows/s/dev |")
    sep = "|" + "---|" * 6
    out = [hdr, sep]
    for r in rows:
        out.append(f"| {r['storage']} | {r['bytes_per_row']} "
                   f"| {r['batch']} | {r['arithmetic_intensity']:.1f} "
                   f"| **{r['bound']}** | {r['grows_per_s']:.2f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--d", type=int, default=64,
                    help="vector dim for the storage-ladder model")
    ap.add_argument("--batch", type=int, nargs="+", default=[64, 256],
                    help="batch sizes for the storage-ladder model")
    ap.add_argument("--pq-m", type=int, default=8)
    args = ap.parse_args()

    art_dir = os.path.dirname(ART)
    os.makedirs(art_dir, exist_ok=True)

    rows = load(args.mesh)
    if rows:
        table = fmt_table(rows)
        out = os.path.join(art_dir, f"roofline_table_{args.mesh}.md")
        with open(out, "w") as f:
            f.write(f"# Roofline — {args.mesh} "
                    f"(per-device terms, v5e constants)\n\n")
            f.write(table + "\n")
        print(table)
        print(f"\nwritten: {out}")
    else:
        print(f"no artifacts for mesh {args.mesh}; skipping artifact table "
              f"(run repro.launch.dryrun to generate)")

    ladder = ladder_rows(args.d, args.batch, args.pq_m)
    lt = fmt_ladder(ladder)
    out = os.path.join(art_dir, "roofline_storage_ladder.md")
    ridge = PEAK_FLOPS / HBM_BW
    with open(out, "w") as f:
        f.write(f"# Serving-scan roofline — storage-dtype ladder "
                f"(d={args.d}, v5e: ridge {ridge:.0f} FLOP/B)\n\n")
        f.write(lt + "\n\n")
        f.write("AI = 2*d*batch / bytes_per_row. A rung left of the ridge "
                "point is HBM-bound: its roof throughput scales inversely "
                "with bytes/row, which is what the int8 rung buys.\n")
    print()
    print(lt)
    print(f"\nwritten: {out}")


if __name__ == "__main__":
    main()
