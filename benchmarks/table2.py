"""Paper Table 2: stability under distribution change.

Three protocols (filter distribution / vector distribution / query pattern);
for each method we report latency increase % and recall degradation (pts)
relative to its own pre-shift baseline — the paper's exact metric.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (default_world, moderate_predicate, timeit)
from repro.core import (FCVIConfig, build, query, ground_truth_combined,
                        recall_at_k, post_filter_search, pre_filter_search,
                        ground_truth_filtered)
from repro.data.synthetic import (sample_queries, shift_filter_distribution,
                                  shift_vector_distribution,
                                  shifted_query_pattern)
from repro.index import flat as flat_mod

K = 10


def _fcvi_eval(idx, q, fq):
    qj, fj = jnp.asarray(q), jnp.asarray(fq)
    t, (_, ids) = timeit(lambda: query(idx, qj, fj, K))
    qn, fqn = idx.transform.normalize(qj, fj)
    _, ref = ground_truth_combined(idx.vectors_n, idx.filters_n, qn, fqn, K,
                                   idx.config.lam)
    return t, float(recall_at_k(ids, ref))


def _baseline_eval(raw, filters, q, pred, mode):
    qj = jnp.asarray(q)
    if mode == "post":
        t, (_, ids) = timeit(
            lambda: post_filter_search(raw, filters, qj, pred, K, oversample=10))
    else:
        t, (_, ids) = timeit(lambda: pre_filter_search(raw, filters, qj, pred, K))
    _, ref = ground_truth_filtered(raw.vectors, filters, qj, pred, K)
    return t, float(recall_at_k(ids, ref))


def run(emit, n=16000, d=64):
    corpus, q, fq = default_world(n=n, d=d)
    v, f = jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters)
    pred = moderate_predicate(corpus)

    cfg = FCVIConfig(alpha=1.0, lam=0.6, c=16.0)
    idx = build(v, f, cfg)
    raw = flat_mod.build(v)

    base = {
        "fcvi": _fcvi_eval(idx, q, fq),
        "post": _baseline_eval(raw, f, q, pred, "post"),
        "pre": _baseline_eval(raw, f, q, pred, "pre"),
    }

    shifts = {
        "filter_dist": shift_filter_distribution(corpus),
        "vector_dist": shift_vector_distribution(corpus),
    }
    for name, shifted in shifts.items():
        sq, sfq = sample_queries(shifted, q.shape[0], seed=77)
        sv = jnp.asarray(shifted.vectors)
        sf = jnp.asarray(shifted.filters)
        # NOTE: indexes are NOT rebuilt — the paper's stability protocol
        sraw = flat_mod.FlatIndex(vectors=sv, sq_norms=jnp.sum(sv * sv, -1)) \
            if name == "vector_dist" else raw
        sfilters = sf
        after = {
            "fcvi": _fcvi_eval(idx, sq, sfq),
            "post": _baseline_eval(sraw, sfilters, sq, pred, "post"),
            "pre": _baseline_eval(sraw, sfilters, sq, pred, "pre"),
        }
        for meth in ("fcvi", "post", "pre"):
            t0, r0 = base[meth]
            t1, r1 = after[meth]
            emit(f"table2/{name}/{meth}/lat_increase_pct",
                 100.0 * (t1 - t0) / t0,
                 f"rec_deg_pts={100*(r0-r1):.1f},base_recall={r0:.3f}")

    # query-pattern shift: same corpus, out-of-pattern queries
    sq, sfq = shifted_query_pattern(corpus, q.shape[0])
    after = {
        "fcvi": _fcvi_eval(idx, sq, sfq),
        "post": _baseline_eval(raw, f, sq, pred, "post"),
        "pre": _baseline_eval(raw, f, sq, pred, "pre"),
    }
    for meth in ("fcvi", "post", "pre"):
        t0, r0 = base[meth]
        t1, r1 = after[meth]
        emit(f"table2/query_pattern/{meth}/lat_increase_pct",
             100.0 * (t1 - t0) / t0,
             f"rec_deg_pts={100*(r0-r1):.1f},base_recall={r0:.3f}")
