"""Benchmark harness — one module per paper table. CSV: name,us_per_call,derived.

  table1       — paper Table 1 (latency/recall/throughput/size/build)
  table2       — paper Table 2 (distribution-shift stability)
  theory_sweep — Thm 5.4 k'(alpha, lambda) validation + kernel micro-bench

Roofline (per paper deliverable g) reads dry-run artifacts separately:
  PYTHONPATH=src python -m benchmarks.roofline
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["table1", "table2", "theory_sweep", None])
    ap.add_argument("--n", type=int, default=20000)
    args = ap.parse_args()

    rows = []

    def emit(name, value, derived=""):
        rows.append((name, value, derived))
        print(f"{name},{value:.4f},{derived}", flush=True)

    print("name,us_per_call,derived")
    if args.only in (None, "table1"):
        from benchmarks import table1
        table1.run(emit, n=args.n)
    if args.only in (None, "table2"):
        from benchmarks import table2
        table2.run(emit, n=min(args.n, 16000))
    if args.only in (None, "theory_sweep"):
        from benchmarks import theory_sweep
        theory_sweep.run(emit, n=min(args.n, 12000))
    print(f"# {len(rows)} measurements", file=sys.stderr)


if __name__ == "__main__":
    main()
