"""End-to-end query-path benchmark: FCVIEngine.search throughput.

The repo's perf-trajectory artifact. Times the serving engine — whose
per-batch hot path is one jax.jit-compiled step — on the flat, IVF and PQ
backends, with and without the Pallas kernels, fp32 and bf16 corpus storage,
at batch sizes 64 and 256, against a live delta buffer (the production
steady state: inserts pending, compaction not yet triggered). Also times a
faithful re-implementation of the pre-batching per-query engine loop
(per-query cache keys + per-query numpy delta merge) as the ``legacy``
baseline, so the speedup of the loop-free path is measured on the same host
and corpus.

Writes BENCH_query_path.json next to this file:

  {"results": [{backend, use_pallas, storage_dtype, batch, qps,
                ms_per_query, bytes_per_query, effective_bandwidth_gbps,
                recall_vs_fp32}, ...],
   "routed": [{backend, routing, filter_mix, qps, shard_skip_rate,
               router_fallback_frac}, ...],
   "filtered": [{backend, filter_mix, plan, est_selectivity, qps,
                 fold_fallback_frac}, ...],
   "legacy": {...}, "speedup_batch64_flat_vs_legacy": ...,
   "speedup_batch64_flat_vs_pr1_jnp": ...}

``bytes_per_query`` is the engine's modeled HBM scan traffic (flat: the
whole slab; IVF: the probed fraction; PQ: the code matrix) divided by
served queries — the number that makes the fp32 -> bf16 -> int8 storage
ladder visible. ``recall_vs_fp32`` compares each reduced-precision row's
final top-k ids against the fp32 row of the same config (1.0 = the
exact-refine pass fully recovered the fp32 ranking).

``--host-devices N`` forces N host (CPU) devices BEFORE jax initialises and
adds mesh-sharded engine rows (flat + IVF on a 1-device and an N-device
mesh), exercising the shard_map batch step end to end, plus the dense-vs-
routed rows on filter-centric (cluster) placement: a selective filter mix
(every query targets one category) against a broad mix, with the fraction
of per-batch shard scans the router skipped and the dense-fallback rate.
NOTE: off-TPU hosts run the Pallas kernels in interpret mode and host
"devices" share the same cores, so ``use_pallas=true`` and ``sharded`` rows
measure dispatch correctness and sharding overhead, not TPU performance.

Usage: PYTHONPATH=src python benchmarks/query_path.py [--n 8192] [--quick]
           [--host-devices 8]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import sys
import time

import numpy as np


def _early_host_devices():
    """XLA reads XLA_FLAGS at first jax init — must run before jax imports.

    Handles both ``--host-devices N`` and ``--host-devices=N``; malformed
    values fall through so argparse can report them properly.
    """
    n = None
    for i, arg in enumerate(sys.argv):
        if arg == "--host-devices" and i + 1 < len(sys.argv):
            n = sys.argv[i + 1]
        elif arg.startswith("--host-devices="):
            n = arg.split("=", 1)[1]
    try:
        n = int(n) if n is not None else 0
    except ValueError:
        return
    if n > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n}")


_early_host_devices()

import jax
import jax.numpy as jnp

from repro.core import FCVIConfig, build, fcvi
from repro.core.filters import F, compile_predicate
from repro.data.synthetic import CorpusSpec, make_corpus, sample_queries
from repro.launch.mesh import make_mesh
from repro.serve.engine import EngineConfig, FCVIEngine

# batch-64 flat jnp engine throughput recorded in PR 1 (pre-jitted step)
PR1_FLAT64_QPS = 1135.0


def legacy_search(engine: FCVIEngine, queries: np.ndarray,
                  filters: np.ndarray):
    """The pre-change engine loop: O(batch) host-side python per query."""
    n = queries.shape[0]
    k = engine.cfg.k
    out_scores = np.zeros((n, k), np.float32)
    out_ids = np.zeros((n, k), np.int64)

    def cache_key(q, f):
        r = engine.cfg.cache_round
        return (np.round(q / r).astype(np.int32).tobytes() + b"#"
                + np.round(f / r).astype(np.int32).tobytes())

    def merge_delta(q, f, scores, ids):
        if not engine._delta_v:
            return scores, ids
        dv = np.concatenate(engine._delta_v)
        df = np.concatenate(engine._delta_f)
        tfm = engine.index.transform
        qn = np.asarray(tfm.vec_norm.apply(jnp.asarray(q[None])))[0]
        fqn = np.asarray(tfm.filt_norm.apply(jnp.asarray(f[None])))[0]
        dvn = np.asarray(tfm.vec_norm.apply(jnp.asarray(dv)))
        dfn = np.asarray(tfm.filt_norm.apply(jnp.asarray(df)))

        def cos(a, b):
            return (a @ b) / (np.linalg.norm(a, axis=-1)
                              * np.linalg.norm(b) + 1e-8)

        lam = engine.index.config.lam
        s = lam * cos(dvn, qn) + (1 - lam) * cos(dfn, fqn)
        base = engine.index.size
        all_s = np.concatenate([scores, s])
        all_i = np.concatenate([ids, base + np.arange(len(s))])
        top = np.argsort(-all_s)[:k]
        return all_s[top].astype(np.float32), all_i[top]

    todo = []
    for i in range(n):
        hit = engine._cache_get(cache_key(queries[i], filters[i]))
        if hit is not None:
            out_scores[i], out_ids[i] = hit
        else:
            todo.append(i)
    bs = engine.cfg.batch_size
    for s in range(0, len(todo), bs):
        idxs = todo[s:s + bs]
        pad = bs - len(idxs)
        q = np.concatenate([queries[idxs],
                            np.zeros((pad, queries.shape[1]), np.float32)])
        f = np.concatenate([filters[idxs],
                            np.zeros((pad, filters.shape[1]), np.float32)])
        scores, ids = engine._staged_query(jnp.asarray(q), jnp.asarray(f), k)
        scores, ids = np.asarray(scores), np.asarray(ids)
        for j, i in enumerate(idxs):
            sc, di = merge_delta(queries[i], filters[i], scores[j], ids[j])
            out_scores[i], out_ids[i] = sc, di
            engine._cache_put(cache_key(queries[i], filters[i]), (sc, di))
    return out_scores, out_ids


def make_engine(corpus, backend: str, use_pallas: bool, batch: int,
                n_delta: int, storage_dtype: str = "float32",
                mesh_devices: int = 0, placement: str = "contiguous",
                routing: str = "dense", alpha: float = 1.0,
                index=None) -> FCVIEngine:
    cfg = FCVIConfig(alpha=alpha, lam=0.6, c=8.0, backend=backend,
                     nlist=64, nprobe=8, pq_ksub=64, pq_coarse=16,
                     use_pallas=use_pallas, storage_dtype=storage_dtype)
    idx = index if index is not None else build(
        jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters), cfg)
    mesh = (make_mesh((mesh_devices, 1), ("data", "model"))
            if mesh_devices else None)
    eng = FCVIEngine(idx, EngineConfig(k=10, batch_size=batch,
                                       compact_threshold=4 * n_delta),
                     mesh=mesh, placement=placement, routing=routing,
                     attributes=(np.asarray(corpus.filters, np.float32)
                                 if backend != "pq" else None))
    if n_delta:
        r = np.random.default_rng(99)
        eng.insert(r.normal(size=(n_delta, corpus.spec.d)).astype(np.float32),
                   corpus.filters[:n_delta].copy())
    return eng


def sample_selective_queries(corpus, n: int, seed: int = 5, cat: int = 1):
    """Filter-selective traffic: every query targets the SAME category filter
    (drawn from that category's rows), the workload filter-centric placement
    concentrates onto few shards. ``cat=1`` picks a mid-size Zipf category —
    the head category genuinely spans several shards by row count alone."""
    rng = np.random.default_rng(seed)
    members = np.nonzero(corpus.cat_labels == cat)[0]
    idx = members[rng.integers(0, len(members), n)]
    q = (corpus.vectors[idx] + 0.25 * corpus.spec.noise
         * rng.normal(size=(n, corpus.spec.d))).astype(np.float32)
    return q, corpus.filters[idx].copy()


def time_search(fn, queries, filters, iters: int):
    fn(queries, filters)                       # warmup (jit compile)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(queries, filters)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--n-delta", type=int, default=512)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="flat backend, batch 64 only")
    ap.add_argument("--host-devices", type=int, default=1,
                    help="force N host devices (set before jax init) and add "
                    "mesh-sharded engine rows on 1- and N-device meshes")
    ap.add_argument("--storage-dtype", default=None,
                    choices=["float32", "bfloat16", "int8"],
                    help="pin every meshless flat/IVF row to one storage "
                    "rung (CI smoke: --quick --storage-dtype int8 exercises "
                    "the quantized scan + exact-refine path end to end)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: BENCH_query_path.json "
                    "next to this script; CI smoke runs point this at a "
                    "scratch path so the committed artifact keeps the full-"
                    "config numbers)")
    args = ap.parse_args()

    spec = CorpusSpec(n=args.n, d=args.d, n_categories=6, n_numeric=2, seed=0)
    corpus = make_corpus(spec)

    # (backend, use_pallas, batch, storage_dtype, mesh_devices [0 = no mesh])
    combos = [("flat", False, 64, "float32", 0),
              ("flat", True, 64, "float32", 0),
              ("flat", False, 64, "bfloat16", 0),
              ("flat", False, 64, "int8", 0)]
    if not args.quick:
        combos += [("flat", False, 256, "float32", 0),
                   ("flat", True, 256, "float32", 0),
                   ("flat", True, 64, "bfloat16", 0),
                   ("flat", True, 64, "int8", 0),
                   ("ivf", False, 64, "float32", 0),
                   ("ivf", True, 64, "float32", 0),
                   ("ivf", False, 256, "float32", 0),
                   ("ivf", True, 256, "float32", 0),
                   ("ivf", False, 64, "bfloat16", 0),
                   ("ivf", False, 64, "int8", 0),
                   ("ivf", True, 64, "int8", 0),
                   ("pq", False, 64, "float32", 0),
                   ("pq", True, 64, "float32", 0)]
    if args.storage_dtype:
        # CI smoke: pin every meshless row to one storage rung
        combos = [(b, up, bt, args.storage_dtype, md) if md == 0 and
                  b != "pq" else (b, up, bt, st, md)
                  for (b, up, bt, st, md) in combos]
        combos = list(dict.fromkeys(combos))
    ndev = min(args.host_devices, len(jax.devices()))
    if ndev > 1:
        # mesh-sharded engine rows: 1-device vs all-device mesh (host
        # "devices" share cores off-TPU — dispatch/overhead check, not speed)
        combos += [("flat", False, 64, "float32", 1),
                   ("flat", False, 64, "float32", ndev)]
        if not args.quick:
            combos += [("ivf", False, 64, "float32", 1),
                       ("ivf", False, 64, "float32", ndev),
                       ("flat", True, 64, "float32", ndev),
                       ("ivf", True, 64, "float32", ndev)]

    results = []
    fp32_ids = {}   # (backend, use_pallas, batch) -> fp32 final ids
    for backend, use_pallas, batch, storage_dtype, mesh_devices in combos:
        q, fq = sample_queries(corpus, batch, seed=1)
        q, fq = np.asarray(q), np.asarray(fq)
        eng = make_engine(corpus, backend, use_pallas, batch, args.n_delta,
                          storage_dtype, mesh_devices)

        def run(queries, filters, eng=eng):
            eng._cache.clear()                 # measure compute, not cache
            return eng.search(queries, filters)

        _, ids = run(q, fq)                    # warmup (jit compile)
        ids = np.asarray(ids)
        eng.stats = type(eng.stats)()          # count timed runs only
        t = time_search(run, q, fq, args.iters)
        st = eng.stats
        row = dict(backend=backend, use_pallas=use_pallas,
                   storage_dtype=storage_dtype, batch=batch,
                   mesh_devices=mesh_devices,
                   qps=batch / t, ms_per_query=1e3 * t / batch,
                   bytes_per_query=round(st.bytes_per_query),
                   effective_bandwidth_gbps=round(
                       st.effective_bandwidth_gbps, 3))
        key = (backend, use_pallas, batch)
        if storage_dtype == "float32" and mesh_devices == 0:
            fp32_ids[key] = ids
        elif mesh_devices == 0 and key in fp32_ids:
            # post-refine recall of the reduced-precision rung vs fp32
            row["recall_vs_fp32"] = round(
                float((ids == fp32_ids[key]).mean()), 4)
        results.append(row)
        print(f"{backend:4s} pallas={int(use_pallas)} "
              f"st={storage_dtype:8s} batch={batch:3d} "
              f"mesh={mesh_devices} "
              f"qps={row['qps']:9.1f}  {row['ms_per_query']:.3f} ms/q  "
              f"{row['bytes_per_query']/1e3:.0f} KB/q"
              + (f"  recall={row['recall_vs_fp32']:.3f}"
                 if "recall_vs_fp32" in row else ""))

    # routed vs dense sharded serving on filter-centric (cluster) placement:
    # alpha=2.0 strengthens the filter fold so selective traffic is
    # geometrically local (the routed win is a geometry property — weakly
    # folded corpora route conservatively and fall back dense more often)
    routed_rows = []
    if ndev > 1:
        for backend in (["flat"] if args.quick else ["flat", "ivf"]):
            idx_cache = {}
            for mix in ("selective", "broad"):
                if mix == "selective":
                    q, fq = sample_selective_queries(corpus, 64)
                else:
                    q, fq = sample_queries(corpus, 64, seed=1)
                    q, fq = np.asarray(q), np.asarray(fq)
                for routing in ("dense", "routed"):
                    eng = make_engine(corpus, backend, False, 64,
                                      args.n_delta, mesh_devices=ndev,
                                      placement="cluster", routing=routing,
                                      alpha=2.0, index=idx_cache.get(backend))
                    idx_cache[backend] = eng.index

                    def run(queries, filters, eng=eng):
                        eng._cache.clear()
                        return eng.search(queries, filters)

                    run(q, fq)                 # warmup (jit compile)
                    eng.stats = type(eng.stats)()  # count timed runs only
                    ts = []
                    for _ in range(args.iters):
                        t0 = time.perf_counter()
                        run(q, fq)
                        ts.append(time.perf_counter() - t0)
                    t = float(np.median(ts))
                    st = eng.stats
                    row = dict(backend=backend, routing=routing,
                               placement="cluster", filter_mix=mix,
                               batch=64, mesh_devices=ndev, alpha=2.0,
                               qps=64 / t, ms_per_query=1e3 * t / 64,
                               shard_skip_rate=round(st.shard_skip_rate, 4),
                               router_fallback_frac=round(
                                   st.router_fallbacks / max(st.queries, 1),
                                   4))
                    routed_rows.append(row)
                    print(f"{backend:4s} {routing:6s} mix={mix:9s} "
                          f"mesh={ndev} qps={row['qps']:9.1f}  "
                          f"skip={row['shard_skip_rate']:.2f} "
                          f"fb={row['router_fallback_frac']:.2f}")

    # degraded-mode serving: 1 of ndev shards dead — qps (the dead shard's
    # cond branch is zero-work, so degraded throughput should not collapse)
    # plus the coverage rate the certificate reports for this traffic
    degraded_rows = []
    if ndev > 1:
        for backend in (["flat"] if args.quick else ["flat", "ivf"]):
            idx_cache = None
            for routing in ("dense", "routed"):
                q, fq = sample_selective_queries(corpus, 64)
                eng = make_engine(corpus, backend, False, 64, args.n_delta,
                                  mesh_devices=ndev, placement="cluster",
                                  routing=routing, alpha=2.0,
                                  index=idx_cache)
                idx_cache = eng.index
                eng.health.mark_dead([ndev - 1])

                def run(queries, filters, eng=eng):
                    eng._cache.clear()
                    return eng.search(queries, filters)

                run(q, fq)                     # warmup (jit compile)
                eng.stats = type(eng.stats)()  # count timed runs only
                ts = []
                for _ in range(args.iters):
                    t0 = time.perf_counter()
                    run(q, fq)
                    ts.append(time.perf_counter() - t0)
                t = float(np.median(ts))
                st = eng.stats
                row = dict(backend=backend, routing=routing,
                           placement="cluster", alpha=2.0, batch=64,
                           mesh_devices=ndev, dead_shards=1,
                           qps=64 / t, ms_per_query=1e3 * t / 64,
                           coverage_rate=round(st.coverage_rate, 4),
                           uncovered_per_batch=round(
                               st.uncovered_queries / max(
                                   st.degraded_batches, 1), 2))
                degraded_rows.append(row)
                print(f"{backend:4s} {routing:6s} DEGRADED 1/{ndev} dead "
                      f"qps={row['qps']:9.1f}  "
                      f"cov={row['coverage_rate']:.2f}")

    # predicate-filtered serving: the general filter algebra across three
    # selectivity bands — the planner's chosen physical plan rides along in
    # each row (fold for broad single-attribute, mask for mid conjunctions,
    # routed for selective predicates on prunable structure)
    filtered_rows = []
    mixes = [
        ("broad_range", F.range("f6", 0.05, 0.95)),
        ("mid_conjunction",
         F.range("f6", 0.2, 0.6) & F.range("f7", 0.0, 0.7)),
        ("narrow_isin_range", F.isin("f4", [1.0]) & F.range("f6", 0.0, 0.15)),
    ]
    for backend in (["flat"] if args.quick else ["flat", "ivf"]):
        eng = make_engine(corpus, backend, False, 64, args.n_delta)
        q, _ = sample_queries(corpus, 64, seed=1)
        q = np.asarray(q)
        for mix, pred in mixes:
            cpp = compile_predicate(pred, eng._attr_names)
            plan = eng.planner.choose(cpp)
            sel = eng.planner.selectivity(cpp)

            def run(queries, filters=None, eng=eng, pred=pred):
                return eng.search(queries, filter=pred)

            t = time_search(run, q, None, args.iters)
            eng.stats = type(eng.stats)()
            run(q)
            st = eng.stats
            row = dict(backend=backend, filter_mix=mix, plan=plan,
                       est_selectivity=round(float(sel), 4), batch=64,
                       qps=64 / t, ms_per_query=1e3 * t / 64,
                       fold_fallback_frac=round(
                           st.filtered_fallbacks / max(st.queries, 1), 4))
            filtered_rows.append(row)
            print(f"{backend:4s} filtered mix={mix:16s} plan={plan:6s} "
                  f"sel={row['est_selectivity']:.3f} "
                  f"qps={row['qps']:9.1f}  "
                  f"fb={row['fold_fallback_frac']:.2f}")

    # legacy per-query loop baseline (jnp kernels off, flat, batch 64)
    q, fq = sample_queries(corpus, 64, seed=1)
    q, fq = np.asarray(q), np.asarray(fq)
    eng = make_engine(corpus, "flat", False, 64, args.n_delta)

    def run_legacy(queries, filters, eng=eng):
        eng._cache.clear()
        return legacy_search(eng, queries, filters)

    t = time_search(run_legacy, q, fq, args.iters)
    legacy = dict(backend="flat", use_pallas=False, batch=64, qps=64 / t,
                  ms_per_query=1e3 * t / 64)
    print(f"legacy loop       batch= 64 qps={legacy['qps']:9.1f}  "
          f"{legacy['ms_per_query']:.3f} ms/q")

    base_dtype = args.storage_dtype or "float32"
    new64 = next(r for r in results
                 if r["backend"] == "flat" and not r["use_pallas"]
                 and r["batch"] == 64 and r["storage_dtype"] == base_dtype
                 and r["mesh_devices"] == 0)
    out = dict(
        config=dict(
            n=args.n, d=args.d, n_delta=args.n_delta, k=10, iters=args.iters,
            host_devices=ndev,
            note=("use_pallas rows run the Pallas kernels in interpret mode "
                  "on non-TPU hosts (dispatch correctness, not TPU perf); "
                  "bytes_per_query / effective_bandwidth_gbps are the "
                  "engine's MODELED HBM scan traffic (slab array sizes x "
                  "probed fraction) per served query — bf16 halves and int8 "
                  "quarters the scanned bytes vs fp32, with recall_vs_fp32 "
                  "= 1.0 after the exact-refine pass; "
                  "the engine batch step is one jax.jit-compiled function; "
                  "mesh_devices>0 rows run the shard_map sharded step — "
                  "forced host devices share cores, so those rows measure "
                  "sharding overhead, not scaling; 'routed' rows compare "
                  "dense vs filter-routed serving on cluster placement "
                  "(alpha=2): shard_skip_rate is the fraction of per-batch "
                  "shard scans the router skipped, router_fallback_frac the "
                  "queries re-run dense because the clipping bound could "
                  "not certify exactness; 'degraded' rows serve the same "
                  "cluster-placed engines with 1 shard marked dead — "
                  "results are bit-identical to a search over surviving "
                  "rows, coverage_rate is the fraction of queries the "
                  "ball-bound/list-ownership certificate proved unaffected "
                  "by the dead shard; 'filtered' rows serve composable "
                  "predicates (range/eq/IN-list conjunctions) through the "
                  "selectivity-aware planner — 'plan' is the physical plan "
                  "it chose (fold/mask/routed), fold_fallback_frac the "
                  "fold-plan queries whose certificate failed and re-ran "
                  "under mask"),
        ),
        results=results,
        routed=routed_rows,
        degraded=degraded_rows,
        filtered=filtered_rows,
        legacy=legacy,
        speedup_batch64_flat_vs_legacy=new64["qps"] / legacy["qps"],
    )
    if args.n == 8192 and args.d == 64 and args.n_delta == 512:
        # PR-1 recorded 1135 qps for this exact flat/jnp/batch-64 config
        # before the engine step was fused into a single jitted function;
        # the ratio is only meaningful for the same corpus shape
        out["speedup_batch64_flat_vs_pr1_jnp"] = new64["qps"] / PR1_FLAT64_QPS
    path = (pathlib.Path(args.out) if args.out
            else pathlib.Path(__file__).parent / "BENCH_query_path.json")
    path.write_text(json.dumps(out, indent=2))
    vs_pr1 = out.get("speedup_batch64_flat_vs_pr1_jnp")
    print(f"speedup (batch-64 flat vs legacy loop): "
          f"{out['speedup_batch64_flat_vs_legacy']:.2f}x"
          + (f"; vs PR-1 jnp baseline: {vs_pr1:.2f}x" if vs_pr1 else "")
          + f" -> {path}")


if __name__ == "__main__":
    main()
