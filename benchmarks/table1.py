"""Paper Table 1: latency / recall@k / throughput / index size / build time
for post-, pre-, hybrid (UNIFY-style) and FCVI x {flat, ivf, pq} backends.

CPU-scale corpus (the paper's metric is RELATIVE behaviour between methods;
see DESIGN.md §6 item 2).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (default_world, fcvi_recall, moderate_predicate,
                               timeit, tree_bytes)
from repro.core import (FCVIConfig, build, query, BoxPredicate,
                        post_filter_search, pre_filter_search, build_hybrid,
                        hybrid_search, ground_truth_filtered, recall_at_k)
from repro.index import flat as flat_mod

K = 10


def run(emit, n=20000, d=64):
    corpus, q, fq = default_world(n=n, d=d)
    v, f = jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters)
    qj = jnp.asarray(q)
    pred = moderate_predicate(corpus)
    _, pred_ref = ground_truth_filtered(v, f, qj, pred, K)
    nq = q.shape[0]

    # ---------- baselines on a raw flat index ----------
    t0 = time.perf_counter()
    raw = flat_mod.build(v)
    raw_build = time.perf_counter() - t0
    raw_bytes = tree_bytes(raw)

    t, (vals, ids) = timeit(
        lambda: post_filter_search(raw, f, qj, pred, K, oversample=10))
    emit("table1/post-flat/latency_ms", t * 1e3 / nq,
         f"recall={float(recall_at_k(ids, pred_ref)):.3f},tput_qps={nq/t:.0f},"
         f"size_mb={raw_bytes/2**20:.1f},build_s={raw_build:.2f}")

    t, (vals, ids) = timeit(lambda: pre_filter_search(raw, f, qj, pred, K))
    emit("table1/pre-flat/latency_ms", t * 1e3 / nq,
         f"recall={float(recall_at_k(ids, pred_ref)):.3f},tput_qps={nq/t:.0f},"
         f"size_mb={raw_bytes/2**20:.1f},build_s={raw_build:.2f}")

    t0 = time.perf_counter()
    hyb = build_hybrid(v, f, key_dim=f.shape[1] - 1, n_segments=32)
    hyb_build = time.perf_counter() - t0
    t, (vals, ids) = timeit(lambda: hybrid_search(hyb, qj, pred, K))
    emit("table1/hybrid-unify/latency_ms", t * 1e3 / nq,
         f"recall={float(recall_at_k(ids, pred_ref)):.3f},tput_qps={nq/t:.0f},"
         f"size_mb={tree_bytes((hyb.flat, hyb.filters))/2**20:.1f},"
         f"build_s={hyb_build:.2f}")

    # ---------- FCVI variants (paper's method) ----------
    from repro.core import multi_probe_query
    probes = np.asarray(pred.probes(4))                    # (r, m) §4.3
    probes_b = jnp.broadcast_to(jnp.asarray(probes)[None],
                                (nq, *probes.shape))
    for backend in ("flat", "ivf", "pq"):
        cfg = FCVIConfig(alpha=1.0, lam=0.6, c=16.0, backend=backend,
                         nlist=64, nprobe=16, pq_m=8, pq_ksub=128)
        t0 = time.perf_counter()
        idx = build(v, f, cfg)
        fcvi_build = time.perf_counter() - t0
        t, (vals, ids) = timeit(
            lambda: query(idx, qj, jnp.asarray(fq), K))
        rec = fcvi_recall(idx, q, fq, K)
        # predicate-mode recall: range predicate -> multi-probe (§4.3)
        _, pids = multi_probe_query(idx, qj, probes_b, K)
        pred_rec = float(recall_at_k(pids, pred_ref))
        emit(f"table1/fcvi-{backend}/latency_ms", t * 1e3 / nq,
             f"recall={rec:.3f},pred_recall={pred_rec:.3f},tput_qps={nq/t:.0f},"
             f"size_mb={tree_bytes(idx.backend)/2**20:.1f},"
             f"build_s={fcvi_build:.2f}")
