"""Assemble EXPERIMENTS.md §Dry-run + §Roofline tables from artifacts.

    PYTHONPATH=src python -m benchmarks.gen_experiments
Prints markdown to stdout (the narrative sections live in EXPERIMENTS.md
itself; this generates the data tables to paste/update).
"""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

MOVE_DOWN = {
    ("compute",): "raise arithmetic efficiency (fuse, larger tiles, drop pad "
                  "waste)",
    ("memory",): "compress the sweep (bf16/int8 corpus or KV, probing)",
    ("collective",): "overlap grad/TP collectives with compute; "
                     "reduce-scatter instead of all-reduce; larger microbatches",
}


def note_for(r):
    t = r["roofline"]["dominant"]
    arch, shape = r["arch"], r["shape"]
    if arch == "fcvi":
        return "corpus sweep is HBM-bound: bf16/PQ corpus or IVF probing"
    if t == "collective":
        if "train" in shape:
            return ("TP activation all-reduces + grad sync dominate: overlap "
                    "with bwd compute, reduce-scatter grads (ZeRO-2)")
        return "seq-parallel KV gathers dominate: head-TP or ring attention"
    if t == "memory":
        if "decode" in shape or "500k" in shape:
            return "KV/state cache sweep: quantize KV (int8), batch more"
        return "activation traffic: fuse norms/residuals into matmuls"
    return "MXU-bound: good — push utilization via tiling/layout"


def row(r, with_useful=True):
    if r["status"] != "ok":
        return (f"| {r['arch']} | {r['shape']} | {r['status']}"
                f" ({r.get('reason','')[:48]}) |  |  |  |  |  |  |")
    t = r["roofline"]
    ma = r["memory_analysis"]
    return (f"| {r['arch']} | {r['shape']} | ok "
            f"| {t['compute_s']:.4g} | {t['memory_s']:.4g} "
            f"| {t['collective_s']:.4g} | **{t['dominant']}** "
            f"| {r['useful_flops_fraction']:.0%} "
            f"| {ma['peak_estimate_bytes']/2**30:.1f} / "
            f"{ma.get('projected_tpu_peak_bytes',0)/2**30:.1f} "
            f"| {note_for(r)} |")


def main():
    for mesh in ("pod16x16", "pod2x16x16"):
        rows = [json.load(open(f))
                for f in sorted(glob.glob(os.path.join(ART, f"*_{mesh}.json")))]
        base = [r for r in rows if "_" not in r["shape"].replace("_", "", 2)
                or True]
        print(f"\n### {mesh}\n")
        print("| arch | shape | status | compute_s | memory_s | collective_s "
              "| dominant | useful | peak GiB (CPU-raw / TPU-proj) | "
              "what moves the dominant term |")
        print("|" + "---|" * 10)
        for r in rows:
            print(row(r))

        ok = [r for r in rows if r["status"] == "ok"]
        coll = sum(r["per_device_collective_bytes"] for r in ok)
        print(f"\ncells ok: {len(ok)}, skipped: "
              f"{sum(r['status']=='skipped' for r in rows)}, "
              f"errors: {sum(r['status']=='error' for r in rows)}")


if __name__ == "__main__":
    main()
