"""Thm 5.4 validation: recall vs k' across (alpha, lambda) — the paper's
parameter-selection guidance, measured.

Also sweeps the Pallas serving kernels against their oracles for the
transform+score+topk hot path (per-call micro-latency).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import default_world, timeit
from repro.core import (FCVIConfig, build, query, ground_truth_combined,
                        recall_at_k, theory)
from repro.kernels import ops, ref

K = 10


def run(emit, n=12000, d=64):
    corpus, q, fq = default_world(n=n, d=d)
    v, f = jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters)
    qj, fj = jnp.asarray(q), jnp.asarray(fq)

    for lam in (0.3, 0.6):
        for alpha in (1.0, 2.0):
            cfg = FCVIConfig(alpha=alpha, lam=lam, c=4.0)
            idx = build(v, f, cfg)
            qn, fqn = idx.transform.normalize(qj, fj)
            _, ref_ids = ground_truth_combined(idx.vectors_n, idx.filters_n,
                                               qn, fqn, K, lam)
            kp_theory = theory.k_prime(K, lam, alpha, n, cfg.c)
            for kp in (K, kp_theory, 4 * kp_theory):
                kp = min(kp, n)
                _, ids = query(idx, qj, fj, K, k_prime=kp)
                rec = float(recall_at_k(ids, ref_ids))
                emit(f"thm54/lam{lam}_a{alpha}/kprime_{kp}", float(kp),
                     f"recall={rec:.3f},theory_kprime={kp_theory}")

    # Pallas serving hot-path micro-bench (interpret mode on CPU)
    corpus_j = v[:4096]
    sq = jnp.sum(corpus_j * corpus_j, -1)
    t, _ = timeit(lambda: ops.score_topk(corpus_j, sq, qj, K))
    emit("kernels/fused_score_topk/us_per_query", t * 1e6 / q.shape[0],
         "pallas_interpret")
    t, _ = timeit(lambda: ops.score_topk(corpus_j, sq, qj, K,
                                         use_pallas=False))
    emit("kernels/score_topk_xla_ref/us_per_query", t * 1e6 / q.shape[0],
         "jnp_oracle")
    P = ref.partition_matrix(d, f.shape[1])
    mv, sv = jnp.zeros(d), jnp.ones(d)
    mf, sf = jnp.zeros(f.shape[1]), jnp.ones(f.shape[1])
    vv = v[:4096]
    ff = f[:4096]
    t, _ = timeit(lambda: ops.fused_transform(vv, ff, P, 2.0, mv, sv, mf, sf))
    emit("kernels/fcvi_transform/us_per_kvec", t * 1e6 / 4.096, "pallas_interpret")
