"""Training launcher: --arch <id> with checkpoint/restart fault tolerance.

On this CPU container it trains the REDUCED config (full configs are
exercised by the dry-run); the step/checkpoint/restart machinery is identical
at scale — on a pod, the same script runs under the production mesh with
per-host data sharding.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --steps 100
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs import get_config, list_archs, reduced
from repro.data.tokens import TokenSpec, global_batch_iterator
from repro.distributed.fault import HeartbeatTracker
from repro.models import model as M
from repro.train import loop as train_loop
from repro.train import optimizer as opt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full-config", action="store_true",
                    help="use the published config (needs a real pod)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = reduced(cfg)
    print(f"arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model} "
          f"pattern={cfg.pattern}")

    adamw = opt.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                            total_steps=args.steps)
    step_fn = jax.jit(train_loop.make_train_step(cfg, adamw))

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    print(f"params: {M.param_count(params):,}")

    start = 0
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        (params, state), start, meta = ckpt.restore(
            args.ckpt_dir, (params, state))
        print(f"resumed from step {start} (meta={meta})")

    extras = {}
    if cfg.enc_dec:
        extras["frames"] = (16, cfg.d_model)
    if cfg.frontend == "vision_stub":
        extras["patches"] = (cfg.n_prefix, cfg.d_model)
    data = global_batch_iterator(
        TokenSpec(vocab_size=cfg.vocab_size, batch=args.batch,
                  seq_len=args.seq, seed=0), extras)

    hb = HeartbeatTracker(n_hosts=jax.process_count())
    t_last = time.perf_counter()
    for i, batch in zip(range(start, args.steps), data):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, state, metrics = step_fn(params, state, batch)
        now = time.perf_counter()
        hb.record(jax.process_index(), i, now - t_last)
        t_last = now
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e}")
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            path = ckpt.save(args.ckpt_dir, i + 1, (params, state),
                             metadata={"arch": cfg.name})
            print(f"checkpointed -> {path}")
        strag = hb.stragglers()
        if strag:
            print(f"stragglers detected: {strag} "
                  "(production: evict + plan_restart)")
    print("done")


if __name__ == "__main__":
    main()
