"""Input specs + sharding spec trees for every (arch x shape) dry-run cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins (weak-type
correct, no allocation) for train/prefill/decode steps; ``*_pspecs`` build
PartitionSpec trees that mirror the exact pytree structures the model
produces (params, optimizer state, caches).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.compat import axis_size, shard_map

from repro.distributed.sharding import AxisRules, param_spec_tree
from repro.models import model as M
from repro.models.layers import COMPUTE_DTYPE
from repro.train import optimizer as opt

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# Per-arch sharding strategy (DESIGN.md §5). Archs whose head count divides
# the 16-way model axis use Megatron TP over heads (default rules); the rest
# shard attention projections over head_dim and run the attention core
# sequence-parallel (shard_map). granite's 40 experts don't divide 16 ->
# experts replicated, per-expert FFN TP over d_ff. xlstm (125M) replicates
# its mixers (TP overhead exceeds any gain at that size — see §Perf).
_SEQ_CORE = {"heads": None, "head_dim": "model",
             "attn_core_seq_shard": "model"}
# serving-only extra rules: at inference there is no gradient sync, so the
# 'data' axis is free capacity for weight sharding — dbrx's 253B expert
# weights get EP over 'model' x per-expert-ff over 'data' (1.0GiB/device).
SERVE_EXTRA_RULES = {
    "dbrx-132b": {"moe_ff": ("pod", "data")},   # pod axis folds away single-pod
}

# training-only: dbrx's 253B expert weights exceed per-device HBM under pure
# 16-way EP -> FSDP the per-expert ff dim over 'data'. Inside the layer scan
# GSPMD all-gathers only the CURRENT layer's slice (true FSDP; the gradient
# transpose becomes a reduce-scatter).
TRAIN_EXTRA_RULES = {
    "dbrx-132b": {"moe_ff": ("pod", "data")},   # FSDP spans pods on 2x16x16
}

ARCH_RULES = {
    "whisper-large-v3": _SEQ_CORE,
    "starcoder2-7b": _SEQ_CORE,
    "gemma3-1b": _SEQ_CORE,
    "recurrentgemma-2b": _SEQ_CORE,
    "granite-moe-3b-a800m": {**_SEQ_CORE, "experts": None, "moe_ff": "model"},
    "xlstm-125m": {"heads": None, "head_dim": None, "rnn": None},
}


def arch_rules(mesh, arch: str, extra: Optional[dict] = None) -> AxisRules:
    rules = dict(ARCH_RULES.get(arch, {}))
    if extra:
        rules.update(extra)
    return AxisRules(mesh, rules)

WHISPER_DEC_LEN = 448  # whisper's decoder context


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def cell_applicable(cfg: M.ModelConfig, shape: str) -> tuple:
    """(runnable, reason) per DESIGN.md §Arch-applicability."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k decode cache skipped"
    return True, ""


# ---------------------------------------------------------------------------
# Input specs
# ---------------------------------------------------------------------------

def train_batch_specs(cfg: M.ModelConfig, seq: int, batch: int) -> dict:
    if cfg.enc_dec:  # whisper: encoder frames carry the seq_len
        return {
            "frames": sds((batch, seq, cfg.d_model), jnp.float32),
            "tokens": sds((batch, WHISPER_DEC_LEN), jnp.int32),
        }
    if cfg.frontend == "vision_stub":
        return {
            "patches": sds((batch, cfg.n_prefix, cfg.d_model), jnp.float32),
            "tokens": sds((batch, seq - cfg.n_prefix), jnp.int32),
        }
    return {"tokens": sds((batch, seq), jnp.int32)}


def prefill_batch_specs(cfg: M.ModelConfig, seq: int, batch: int) -> dict:
    return train_batch_specs(cfg, seq, batch)


def decode_input_specs(cfg: M.ModelConfig, seq: int, batch: int):
    """(token_sds, cache_sds) — cache via eval_shape (no allocation)."""
    token = sds((batch, 1), jnp.int32)

    def build_cache():
        if cfg.enc_dec:
            self_c = M.init_cache(cfg, batch, max_len=512)
            cross = M.init_cross_cache(cfg, batch, enc_len=seq)
            return {"self": self_c, "cross": cross}
        return {"self": M.init_cache(cfg, batch, max_len=seq), "cross": None}

    cache = jax.eval_shape(build_cache)
    return token, cache


# ---------------------------------------------------------------------------
# Sharding spec trees
# ---------------------------------------------------------------------------

def batch_pspecs(cfg: M.ModelConfig, batch_specs: dict, rules: AxisRules) -> dict:
    out = {}
    for k, v in batch_specs.items():
        names = ["batch"] + [None] * (len(v.shape) - 1)
        out[k] = rules.spec(*names)
    return out


def _kv_cache_pspec(rules: AxisRules, lead: tuple):
    return {
        "k": rules.spec(*lead, "batch", "kv_seq", "kv_heads", None),
        "v": rules.spec(*lead, "batch", "kv_seq", "kv_heads", None),
        "slot_pos": rules.spec(*lead, None),
        "pos": rules.spec(*lead),
    }


def _block_cache_pspec(cfg, kind: str, rules: AxisRules, lead: tuple):
    if kind in ("attn", "local"):
        return _kv_cache_pspec(rules, lead)
    if kind == "rec":
        return {"h": rules.spec(*lead, "batch", "rnn"),
                "conv": rules.spec(*lead, "batch", None, "rnn")}
    if kind == "mlstm":
        return {"C": rules.spec(*lead, "batch", "heads", None, None),
                "n": rules.spec(*lead, "batch", "heads", None),
                "m": rules.spec(*lead, "batch", "heads")}
    if kind == "slstm":
        v = rules.spec(*lead, "batch", "heads", None)
        return {"c": v, "n": v, "h": v, "m": v}
    raise ValueError(kind)


def cache_pspecs(cfg: M.ModelConfig, rules: AxisRules, enc_dec_cross: bool):
    scan_c = [_block_cache_pspec(cfg, kind, rules, ("none",))
              for kind in cfg.pattern] if cfg.n_periods else []
    rest_c = [_block_cache_pspec(cfg, kind, rules, ())
              for kind in cfg.rest_kinds]
    self_spec = {"scan": scan_c, "rest": rest_c}
    cross = None
    if enc_dec_cross:
        kv = rules.spec("none", "batch", "kv_seq", "kv_heads", None)
        kv1 = rules.spec("batch", "kv_seq", "kv_heads", None)
        cross = {"scan": [(kv, kv) for _ in cfg.pattern] if cfg.n_periods else [],
                 "rest": [(kv1, kv1) for _ in cfg.rest_kinds]}
    return {"self": self_spec, "cross": cross}


def zero1_specs(param_sds, base_specs, rules: AxisRules):
    """Additionally shard optimizer moments over the data axis (ZeRO-1).

    For each leaf, the first unsharded dim divisible by the data-axis size
    takes 'data'. Falls back to the base spec when nothing divides.
    """
    data_axis = rules.rules.get("batch")
    if data_axis is None:
        return base_specs
    if isinstance(data_axis, tuple):
        data_axis = data_axis[-1]  # shard moments within-pod only
    size = dict(zip(rules.mesh.axis_names, rules.mesh.devices.shape))[data_axis]

    def one(sd, spec):
        entries = list(spec) + [None] * (len(sd.shape) - len(spec))
        used = set()
        for e in entries:
            for a in (e if isinstance(e, tuple) else (e,)):
                if a:
                    used.add(a)
        if data_axis in used:
            return spec  # leaf already FSDP-sharded over the data axis
        for i, (dim, e) in enumerate(zip(sd.shape, entries)):
            if e is None and dim % size == 0 and dim >= size:
                entries[i] = data_axis
                return P(*entries)
        return spec

    return jax.tree.map(one, param_sds, base_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Cell assembly: everything dryrun.py needs for one (arch, shape)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    step_fn: object
    in_sds: tuple
    in_pspecs: tuple
    out_pspecs: object
    rules: object = None
    donate: tuple = ()


def build_cell(cfg: M.ModelConfig, arch: str, shape: str, mesh,
               n_micro: int = 1, extra_rules: Optional[dict] = None) -> Optional[Cell]:
    ok, _ = cell_applicable(cfg, shape)
    if not ok:
        return None
    info = SHAPES[shape]
    seq, batch = info["seq"], info["batch"]
    extra = dict(extra_rules or {})
    if info["kind"] in ("prefill", "decode"):
        extra = {**SERVE_EXTRA_RULES.get(arch, {}), **extra}
    if info["kind"] == "train":
        extra = {**TRAIN_EXTRA_RULES.get(arch, {}), **extra}
    if info["kind"] == "decode" and batch == 1:
        # long-context decode: replicate batch, KV sequence over data x model
        extra.setdefault("batch", None)
        extra.setdefault("kv_seq", ("data", "model"))
    rules = arch_rules(mesh, arch, extra)

    if info["kind"] == "train":
        from jax.sharding import NamedSharding
        from repro.train import loop as train_loop
        # per-microbatch batch must stay divisible by the DP degree
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp_axes = rules.rules.get("batch") or ()
        dp_axes = dp_axes if isinstance(dp_axes, tuple) else (dp_axes,)
        dp = 1
        for a in dp_axes:
            dp *= sizes.get(a, 1)
        while n_micro > 1 and (batch // n_micro) % max(dp, 1):
            n_micro //= 2
        adamw = opt.AdamWConfig()
        rng = jax.random.PRNGKey(0)
        param_sds = jax.eval_shape(functools.partial(M.init_params, cfg=cfg), rng)
        # production mixed precision: bf16 compute params + f32 master/moments
        param_sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16), param_sds)
        opt_sds = jax.eval_shape(opt.init, param_sds)
        batch_sds = train_batch_specs(cfg, seq, batch)

        p_specs = param_spec_tree(param_sds, rules)
        mom_specs = zero1_specs(param_sds, p_specs, rules)
        o_specs = opt.AdamWState(step=P(), mu=mom_specs, nu=mom_specs,
                                 master=mom_specs)
        grad_sh = jax.tree.map(lambda sp: NamedSharding(mesh, sp), mom_specs,
                               is_leaf=lambda x: isinstance(x, P))
        step = train_loop.make_train_step(cfg, adamw, n_micro=n_micro,
                                          grad_shardings=grad_sh)
        b_specs = batch_pspecs(cfg, batch_sds, rules)
        metric_specs = {"loss": P(), "ppl_log": P(), "tokens": P(),
                        "logz_mean": P(), "grad_norm": P(), "lr": P()}
        if n_micro > 1:
            metric_specs = {"loss": P(), "grad_norm": P(), "lr": P()}
        return Cell(arch, shape, "train", step,
                    in_sds=(param_sds, opt_sds, batch_sds),
                    in_pspecs=(p_specs, o_specs, b_specs),
                    out_pspecs=(p_specs, o_specs, metric_specs),
                    rules=rules, donate=(0, 1))

    if info["kind"] == "prefill":
        def prefill_step(params, batch_in):
            return M.prefill(params, cfg, batch_in, max_len=seq)

        rng = jax.random.PRNGKey(0)
        param_sds = jax.eval_shape(functools.partial(M.init_params, cfg=cfg), rng)
        param_sds = jax.tree.map(  # serving weights are bf16
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16), param_sds)
        batch_sds = prefill_batch_specs(cfg, seq, batch)
        p_specs = param_spec_tree(param_sds, rules)
        b_specs = batch_pspecs(cfg, batch_sds, rules)
        logits_spec = rules.spec("batch", None, "vocab")
        c_specs = cache_pspecs(cfg, rules, enc_dec_cross=cfg.enc_dec)
        return Cell(arch, shape, "prefill", prefill_step,
                    in_sds=(param_sds, batch_sds),
                    in_pspecs=(p_specs, b_specs),
                    out_pspecs=(logits_spec, c_specs), rules=rules)

    # decode
    def serve_step(params, token, cache):
        return M.decode_step(params, cfg, token, cache)

    rng = jax.random.PRNGKey(0)
    param_sds = jax.eval_shape(functools.partial(M.init_params, cfg=cfg), rng)
    param_sds = jax.tree.map(  # serving weights are bf16
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16), param_sds)
    token_sds, cache_sds = decode_input_specs(cfg, seq, batch)
    p_specs = param_spec_tree(param_sds, rules)
    t_spec = rules.spec("batch", None)
    c_specs = cache_pspecs(cfg, rules, enc_dec_cross=cfg.enc_dec)
    logits_spec = rules.spec("batch", None, "vocab")
    return Cell(arch, shape, "decode", serve_step,
                in_sds=(param_sds, token_sds, cache_sds),
                in_pspecs=(p_specs, t_spec, c_specs),
                out_pspecs=(logits_spec, c_specs),
                rules=rules, donate=(2,))


# ---------------------------------------------------------------------------
# FCVI serving cell — the paper's technique on the production mesh
# ---------------------------------------------------------------------------

FCVI_SHAPES = {
    # 268M corpus vectors (SIFT-like d=128, m=8 filters), 1024-query batches
    "serve_268m": dict(n=1 << 28, d=128, m=8, batch=1024, k=100, kprime=400),
}


def build_fcvi_cell(shape: str, mesh, extra_rules: Optional[dict] = None,
                    variant: str = "base"):
    """Distributed FCVI query step: psi-transform -> sharded top-k'
    (tree merge over model then data axes) -> combined-score re-rank.

    Variants (§Perf hillclimb on the paper's technique):
      base  — exact f32 corpus sweep (paper-faithful FCVI-Flat)
      bf16  — bf16 transformed corpus (halves the HBM sweep; rescore stays f32)
      ivf8  — FCVI-IVF layout: each shard holds 64 lists, probes the top-8
              (1/8 of local rows scored; beyond-paper on TPU, paper-sanctioned
              backend swap)
    """
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P2
    from repro.core.transform import psi_partition
    from repro.index.distributed import sharded_search_fn

    info = FCVI_SHAPES[shape]
    n, d, m = info["n"], info["d"], info["m"]
    batch, k, kprime = info["batch"], info["k"], info["kprime"]
    lam, alpha = 0.5, 1.0
    rules = AxisRules(mesh, {**(extra_rules or {})})
    corpus_axes = tuple(a for a in ("pod", "data", "model")
                        if a in mesh.axis_names)
    n_shards = 1
    for a in corpus_axes:
        n_shards *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    corpus_dtype = jnp.bfloat16 if variant in ("bf16", "ivf8", "ivf8-trunc", "opt") else jnp.float32

    k_local = 64 if variant in ("ivf8-trunc", "opt") else 0
    local_rescore = variant == "opt"
    if variant in ("ivf8", "ivf8-trunc", "opt"):
        nlist_loc, nprobe = 64, 8
        n_loc = n // n_shards
        list_sz = n_loc // nlist_loc

        def serve_step(grouped, grouped_sq, centroids, vectors_n, filters_n,
                       q, fq):
            # grouped: (S, nlist, list_sz, d) shard-major IVF layout
            q_t = psi_partition(q, fq, alpha)

            def local(gr, gsq, cen):
                gr, gsq, cen = gr[0], gsq[0], cen[0]
                cd = q_t @ cen.T                          # (batch, nlist)
                _, probes = jax.lax.top_k(cd, nprobe)     # (batch, nprobe)

                qc = 64                                   # query chunk: bounds
                nqc = batch // qc                         # the probed gather

                def chunk(i):
                    qs = jax.lax.dynamic_slice_in_dim(q_t, i * qc, qc, 0)
                    pr = jax.lax.dynamic_slice_in_dim(probes, i * qc, qc, 0)
                    rows = gr[pr]                         # (qc, nprobe, ls, d)
                    rsq = gsq[pr]
                    sc = (2.0 * jnp.einsum("bd,bpld->bpl",
                                           qs.astype(rows.dtype), rows
                                           ).astype(jnp.float32) - rsq)
                    sc = sc.reshape(qc, nprobe * list_sz)
                    v, ix = jax.lax.top_k(sc, kprime)
                    flat = (pr[:, :, None] * list_sz
                            + jnp.arange(list_sz)[None, None, :]
                            ).reshape(qc, -1)
                    return v, jnp.take_along_axis(flat, ix, axis=-1)

                _, (vals, gidx) = jax.lax.scan(
                    lambda _, i: (None, chunk(i)), None, jnp.arange(nqc))
                vals = vals.reshape(batch, kprime)
                gidx = gidx.reshape(batch, kprime)
                if k_local:  # truncate candidates before the merge tree
                    vals = vals[:, :k_local]
                    gidx = gidx[:, :k_local]
                # globalise ids and tree-merge over the corpus axes
                offset = jnp.int32(0)
                stride = n_loc
                for ax in reversed(corpus_axes):
                    offset = offset + jax.lax.axis_index(ax) * stride
                    stride = stride * axis_size(ax)
                gidx = gidx + offset
                from repro.index.distributed import _merge_over_axis
                for i, ax in enumerate(reversed(corpus_axes)):
                    keep = kprime if i == len(corpus_axes) - 1 else \
                        (k_local or kprime)
                    vals, gidx = _merge_over_axis(vals, gidx, ax, keep)
                return vals, gidx

            _, cand = shard_map(
                local, mesh=mesh,
                in_specs=(P2(corpus_axes), P2(corpus_axes), P2(corpus_axes)),
                out_specs=(P2(), P2()), check_vma=False)(
                grouped, grouped_sq, centroids)

            if local_rescore:
                # compute-to-data re-scoring: each shard scores ITS candidate
                # rows and psums 4 small (b, k') partials — the 210MB
                # candidate-vector gather becomes ~6MB of score traffic.
                def rescore(vn, fn):
                    n_loc2 = vn.shape[0]
                    offset = jnp.int32(0)
                    stride = n_loc2
                    for ax in reversed(corpus_axes):
                        offset = offset + jax.lax.axis_index(ax) * stride
                        stride = stride * axis_size(ax)
                    lid = cand - offset
                    own = (lid >= 0) & (lid < n_loc2)
                    safe = jnp.clip(lid, 0, n_loc2 - 1)
                    cv = vn[safe] * own[..., None]
                    cf = fn[safe] * own[..., None]
                    parts = jnp.stack([
                        jnp.sum(cv * q[:, None, :], -1),
                        jnp.linalg.norm(cv, axis=-1),
                        jnp.sum(cf * fq[:, None, :], -1),
                        jnp.linalg.norm(cf, axis=-1)])
                    for ax in corpus_axes:
                        parts = jax.lax.psum(parts, ax)
                    nv, dv, nf, df = parts
                    qn = jnp.linalg.norm(q, axis=-1)[:, None]
                    fqn = jnp.linalg.norm(fq, axis=-1)[:, None]
                    return (lam * nv / (dv * qn + 1e-8)
                            + (1 - lam) * nf / (df * fqn + 1e-8))

                score = shard_map(
                    rescore, mesh=mesh,
                    in_specs=(P2(corpus_axes), P2(corpus_axes)),
                    out_specs=P2(), check_vma=False)(vectors_n, filters_n)
            else:
                cv = vectors_n[cand].astype(jnp.float32)
                cf = filters_n[cand]

                def cos(candt, qv):
                    num = jnp.sum(candt * qv[:, None, :], axis=-1)
                    den = (jnp.linalg.norm(candt, axis=-1)
                           * jnp.linalg.norm(qv, axis=-1)[:, None] + 1e-8)
                    return num / den

                score = lam * cos(cv, q) + (1 - lam) * cos(cf, fq)
            vals, pos = jax.lax.top_k(score, k)
            return vals, jnp.take_along_axis(cand, pos, axis=-1)

        in_sds = (
            sds((n_shards, nlist_loc, list_sz, d), corpus_dtype),
            sds((n_shards, nlist_loc, list_sz), jnp.float32),
            sds((n_shards, nlist_loc, d), jnp.float32),
            sds((n, d), jnp.float32), sds((n, m), jnp.float32),
            sds((batch, d), jnp.float32), sds((batch, m), jnp.float32),
        )
        row = P(corpus_axes)
        in_pspecs = (row, row, row, P(corpus_axes, None),
                     P(corpus_axes, None), P(), P())
        return Cell("fcvi", shape, "fcvi_serve", serve_step,
                    in_sds=in_sds, in_pspecs=in_pspecs,
                    out_pspecs=(P(), P()), rules=rules)

    search = sharded_search_fn(mesh, corpus_axes, kprime,
                               k_local=k_local)

    def serve_step(corpus_t, sq_norms, vectors_n, filters_n, q, fq):
        q_t = psi_partition(q, fq, alpha).astype(corpus_dtype)
        _, cand = search(corpus_t, sq_norms, q_t)          # (batch, k')
        cv = vectors_n[cand].astype(jnp.float32)           # (batch, k', d)
        cf = filters_n[cand]

        def cos(cand, qv):  # cand: (b, k', x); qv: (b, x)
            num = jnp.sum(cand * qv[:, None, :], axis=-1)
            den = (jnp.linalg.norm(cand, axis=-1)
                   * jnp.linalg.norm(qv, axis=-1)[:, None] + 1e-8)
            return num / den

        score = lam * cos(cv, q) + (1 - lam) * cos(cf, fq)
        vals, pos = jax.lax.top_k(score, k)
        return vals, jnp.take_along_axis(cand, pos, axis=-1)

    row = P(corpus_axes)
    in_sds = (
        sds((n, d), corpus_dtype), sds((n,), jnp.float32),
        sds((n, d), jnp.float32), sds((n, m), jnp.float32),
        sds((batch, d), jnp.float32), sds((batch, m), jnp.float32),
    )
    in_pspecs = (P(corpus_axes, None), row, P(corpus_axes, None),
                 P(corpus_axes, None), P(), P())
    out_pspecs = (P(), P())
    return Cell("fcvi", shape, "fcvi_serve", serve_step,
                in_sds=in_sds, in_pspecs=in_pspecs, out_pspecs=out_pspecs,
                rules=rules)
