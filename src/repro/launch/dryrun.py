import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import — jax locks the device
count at first init. 512 placeholder host devices back the production meshes
(16x16 single-pod, 2x16x16 multi-pod); nothing is allocated or executed:
inputs are ShapeDtypeStructs and the deliverable is the compiled artifact's
memory_analysis / cost_analysis / collective schedule, written to
artifacts/dryrun/<arch>_<shape>_<mesh>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.distributed.sharding import AxisRules, use_rules
from repro.launch import hlo_analysis as H
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import model as M

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")

# per-(arch, shape) microbatching for train cells that need activation relief
N_MICRO = {
    ("dbrx-132b", "train_4k"): 16,
    ("internvl2-26b", "train_4k"): 8,
    ("gemma2-27b", "train_4k"): 8,
    ("mistral-nemo-12b", "train_4k"): 8,
    ("whisper-large-v3", "train_4k"): 8,
    ("starcoder2-7b", "train_4k"): 4,
    ("gemma3-1b", "train_4k"): 2,
    ("recurrentgemma-2b", "train_4k"): 2,
    ("granite-moe-3b-a800m", "train_4k"): 4,
}


def to_shardings(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def _bf16_arg_bytes_per_device(mesh, cell) -> int:
    """Per-device bytes of bf16 input arguments (weights + caches)."""
    import numpy as np
    import jax.numpy as jnp
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def shard_div(spec, shape):
        div = 1
        entries = list(spec) if spec is not None else []
        for e in entries[: len(shape)]:
            if e is None:
                continue
            axes = e if isinstance(e, tuple) else (e,)
            for a in axes:
                div *= sizes.get(a, 1)
        return div

    total = 0
    for sds_tree, spec_tree in zip(cell.in_sds, cell.in_pspecs):
        leaves_s = jax.tree_util.tree_leaves(sds_tree)
        leaves_p = jax.tree_util.tree_leaves(
            spec_tree, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        if len(leaves_p) != len(leaves_s):
            leaves_p = [None] * len(leaves_s)
        for sd, sp in zip(leaves_s, leaves_p):
            if sd.dtype == jnp.bfloat16:
                n = int(np.prod(sd.shape)) if sd.shape else 1
                total += (n * 2) // max(shard_div(sp, sd.shape), 1)
    return total


def active_params(cfg: M.ModelConfig, param_sds) -> int:
    """Active (per-token) parameter count — experts counted top_k/E."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(param_sds)[0]:
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        n = 1
        for d in leaf.shape:
            n *= d
        if cfg.is_moe and ("we_in" in pstr or "we_gate" in pstr or "we_out" in pstr):
            n = int(n * cfg.moe_top_k / cfg.moe_experts)
        total += n
    return total


def tokens_of(cfg, shape: str) -> int:
    info = S.SHAPES[shape]
    if info["kind"] == "train":
        seq = S.WHISPER_DEC_LEN + info["seq"] if cfg.enc_dec else info["seq"]
        return info["batch"] * seq
    if info["kind"] == "prefill":
        return info["batch"] * info["seq"]
    return info["batch"]  # decode: 1 new token per sequence


# §Perf hillclimb variants: hypothesis -> change, measured against the
# baseline artifact of the same (arch, shape). See EXPERIMENTS.md §Perf.
VARIANTS = {
    # H1: xlstm replicates its mixers over 'model' (16x redundant compute,
    # useful=11%). Change: pure 256-way DP (batch over data x model).
    "xlstm-dp256": dict(arch="xlstm-125m", shape="train_4k",
                        extra_rules={"batch": ("data", "model"),
                                     "vocab": None}),  # pure 256-way DP
    # H2: granite's replicated-experts MoE with ff TP psums the full
    # (G,E,C,d) out_buf every layer. Change: replicate expert ff too
    # (zero MoE collectives, ~5x cheap expert FLOPs).
    "granite-repl-ff": dict(arch="granite-moe-3b-a800m", shape="train_4k",
                            extra_rules={"moe_ff": None}),
    # H2b: granite intermediate — experts replicated but ZeRO over data only
    "granite-repl-ff-m4": dict(arch="granite-moe-3b-a800m", shape="train_4k",
                               extra_rules={"moe_ff": None}, n_micro=2),
    # H3: the paper's own serving step — precision + probing ladders
    "fcvi-bf16": dict(arch="fcvi", shape="serve_268m", fcvi_variant="bf16"),
    "fcvi-ivf8": dict(arch="fcvi", shape="serve_268m", fcvi_variant="ivf8"),
    # H3 iter 3: probing leaves the k'-merge all-gathers dominant -> truncate
    # per-shard candidates to top-64 before the merge tree
    "fcvi-ivf8-trunc": dict(arch="fcvi", shape="serve_268m",
                            fcvi_variant="ivf8-trunc"),
    # H3 iter 4: the rescore gather moves 210MB of candidate vectors ->
    # compute-to-data partial cosines + psum of 4x(b,k') scores (~6MB)
    "fcvi-opt": dict(arch="fcvi", shape="serve_268m", fcvi_variant="opt"),
}


def run_fcvi_cell(shape: str, multi_pod: bool, verbose: bool = True,
                  fcvi_variant: str = "base", tag: str = "") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    result = {"arch": "fcvi", "shape": shape + tag, "mesh": mesh_name,
              "variant": fcvi_variant}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = S.build_fcvi_cell(shape, mesh, variant=fcvi_variant)
    with use_rules(cell.rules):
        jitted = jax.jit(cell.step_fn,
                         in_shardings=to_shardings(mesh, cell.in_pspecs),
                         out_shardings=to_shardings(mesh, cell.out_pspecs))
        lowered = jitted.lower(*cell.in_sds)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    hc = H.analyze(hlo)
    bytes_acc = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + 2 * ma.temp_size_in_bytes - 2 * ma.alias_size_in_bytes)
    terms = H.roofline_terms(hc["flops"], bytes_acc, hc["collective_bytes"])
    info = S.FCVI_SHAPES[shape]
    # useful work: 2*N*d FLOPs of exact scoring per query batch
    mf = 2.0 * info["n"] * info["d"] * info["batch"]
    n_dev = mesh.devices.size
    result.update(
        status="ok", kind="fcvi_serve", n_micro=1,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory_analysis={
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        per_device_flops=hc["flops"], per_device_bytes=bytes_acc,
        per_device_collective_bytes=hc["collective_bytes"],
        collectives=hc["collectives"], roofline=terms,
        params_total=0, params_active=0, model_flops_global=mf,
        useful_flops_fraction=mf / (hc["flops"] * n_dev) if hc["flops"] else 0,
        hlo_len=len(hlo), hlo_text_bytes=hc["bytes"],
    )
    if verbose:
        peak_gb = result["memory_analysis"]["peak_estimate_bytes"] / 2**30
        print(f"[fcvi {shape} {mesh_name}] ok lower={t_lower:.1f}s "
              f"compile={t_compile:.1f}s peak/dev={peak_gb:.2f}GiB "
              f"flops/dev={hc['flops']:.3g} coll/dev={hc['collective_bytes']:.3g}B "
              f"dominant={terms['dominant']} useful={result['useful_flops_fraction']:.2%}")
    return result


def run_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True,
             extra_rules=None, n_micro_override=None, tag: str = "") -> dict:
    if arch == "fcvi":
        return run_fcvi_cell(shape, multi_pod, verbose, tag=tag)
    cfg = get_config(arch)
    ok, reason = S.cell_applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    result = {"arch": arch, "shape": shape + tag, "mesh": mesh_name}
    if not ok:
        result.update(status="skipped", reason=reason)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_micro = n_micro_override or N_MICRO.get((arch, shape), 1)

    t0 = time.time()
    cell = S.build_cell(cfg, arch, shape, mesh, n_micro=n_micro,
                        extra_rules=extra_rules)
    with use_rules(cell.rules):
        jitted = jax.jit(
            cell.step_fn,
            in_shardings=to_shardings(mesh, cell.in_pspecs),
            out_shardings=to_shardings(mesh, cell.out_pspecs),
            donate_argnums=cell.donate,
        )
        lowered = jitted.lower(*cell.in_sds)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    hlo = compiled.as_text()
    hc = H.analyze(hlo)          # loop-aware HLO accounting (per-device)
    colls = hc["collectives"]
    coll_bytes = hc["collective_bytes"]
    flops = hc["flops"]
    # memory-traffic model: every argument/output touched once, every live
    # temp written + read once. (HLO-text bytes kept as diagnostic — it
    # overcounts buffers referenced from loop-body fusions.)
    bytes_acc = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + 2 * ma.temp_size_in_bytes - 2 * ma.alias_size_in_bytes)
    terms = H.roofline_terms(flops, bytes_acc, coll_bytes)
    xla_flops = float(ca.get("flops", 0.0)) if isinstance(ca, dict) else 0.0

    # CPU-backend correction: XLA:CPU materialises f32 copies of every bf16
    # dot operand (no native bf16 GEMM) and hoists stacked-weight converts
    # out of the layer loop; the TPU MXU consumes bf16 natively. Projected
    # TPU peak subtracts those 2x-bf16-argument copies.
    bf16_arg_bytes = _bf16_arg_bytes_per_device(mesh, cell)
    projected_tpu_peak = max(
        0,
        ma.argument_size_in_bytes + ma.output_size_in_bytes
        + ma.temp_size_in_bytes - ma.alias_size_in_bytes
        - 2 * bf16_arg_bytes)

    param_sds = cell.in_sds[0]
    n_active = active_params(cfg, param_sds)
    n_total = sum(int(__import__("numpy").prod(l.shape))
                  for l in jax.tree.leaves(param_sds))
    mf = H.model_flops(n_active, tokens_of(cfg, shape), cell.kind)
    n_dev = mesh.devices.size
    useful = mf / (flops * n_dev) if flops else 0.0

    result.update(
        status="ok",
        kind=cell.kind,
        n_micro=n_micro,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory_analysis={
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
            "projected_tpu_peak_bytes": projected_tpu_peak,
            "bf16_arg_bytes_per_device": bf16_arg_bytes,
        },
        per_device_flops=flops,
        per_device_bytes=bytes_acc,
        xla_cost_analysis_flops=xla_flops,
        hlo_text_bytes=hc["bytes"],
        per_device_collective_bytes=coll_bytes,
        collectives=colls,
        roofline=terms,
        params_total=n_total,
        params_active=n_active,
        model_flops_global=mf,
        useful_flops_fraction=useful,
        hlo_len=len(hlo),
    )
    if verbose:
        peak_gb = result["memory_analysis"]["peak_estimate_bytes"] / 2**30
        print(f"[{arch} {shape} {mesh_name}] ok "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s "
              f"peak/dev={peak_gb:.2f}GiB flops/dev={flops:.3g} "
              f"coll/dev={coll_bytes:.3g}B dominant={terms['dominant']} "
              f"useful={useful:.2%}")
        print(f"  memory_analysis: {ma}")
    return result


def save_result(res: dict):
    os.makedirs(ART_DIR, exist_ok=True)
    name = f"{res['arch']}_{res['shape']}_{res['mesh']}.json"
    with open(os.path.join(ART_DIR, name), "w") as f:
        json.dump(res, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(S.SHAPES) + list(S.FCVI_SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default=None, choices=list(VARIANTS))
    args = ap.parse_args()

    if args.variant:
        v = VARIANTS[args.variant]
        meshes = {"single": [False], "multi": [True],
                  "both": [False, True]}[args.mesh]
        for mp in meshes:
            if v["arch"] == "fcvi":
                res = run_fcvi_cell(v["shape"], mp,
                                    fcvi_variant=v["fcvi_variant"],
                                    tag="_" + args.variant)
            else:
                res = run_cell(v["arch"], v["shape"], mp,
                               extra_rules=v.get("extra_rules"),
                               n_micro_override=v.get("n_micro"),
                               tag="_" + args.variant)
            save_result(res)
        return

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(S.SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    cells = [(a, sh) for a in archs for sh in shapes if a != "fcvi"]
    if args.all or args.arch == "fcvi":
        fshapes = list(S.FCVI_SHAPES) if args.shape is None else \
            [sh for sh in [args.shape] if sh in S.FCVI_SHAPES]
        cells += [("fcvi", sh) for sh in fshapes]
    if args.arch == "fcvi":
        cells = [(a, sh) for (a, sh) in cells if a == "fcvi"]

    failures = []
    for arch, shape in cells:
            for mp in meshes:
                if args.skip_existing:
                    nm = f"{arch}_{shape}_{'pod2x16x16' if mp else 'pod16x16'}.json"
                    pth = os.path.join(ART_DIR, nm)
                    if os.path.exists(pth):
                        with open(pth) as fh:
                            if json.load(fh).get("status") in ("ok", "skipped"):
                                print(f"[{arch} {shape} mp={mp}] cached, skipping")
                                continue
                try:
                    res = run_cell(arch, shape, mp)
                except Exception as e:
                    traceback.print_exc()
                    res = {"arch": arch, "shape": shape,
                           "mesh": "pod2x16x16" if mp else "pod16x16",
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                    failures.append(res)
                save_result(res)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f["arch"], f["shape"], f["mesh"], f["error"])
        raise SystemExit(1)
    print("\nall requested cells compiled OK")


if __name__ == "__main__":
    main()
