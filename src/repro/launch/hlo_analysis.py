"""Roofline-term extraction from compiled AOT artifacts.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified:
an 8-step scan reports 1/8 the FLOPs of the unrolled loop), which breaks
accounting for scan-over-layers models. This module parses the partitioned
HLO text instead:

  * computations are split into blocks; ``while`` ops carry
    ``backend_config known_trip_count`` -> bodies are expanded x trip;
  * dot FLOPs = 2 x |result| x |contraction| from the typed operands;
  * HBM traffic is counted at fusion boundaries (operands + results of each
    top-level op — fusion internals stay on-chip), data-movement ops
    (bitcast/gte/tuple/param/constant/copy) are free;
  * collective bytes = operand bytes of every collective op, bucketed by
    kind and replica-group size (identifies the mesh axis), expanded by
    loop trip counts like everything else.

All quantities are PER-DEVICE (the HLO is the partitioned SPMD module).
"""
from __future__ import annotations

import json
import re
from typing import Optional

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

FREE_OPS = {"get-tuple-element", "parameter", "constant", "tuple", "bitcast",
            "copy", "copy-start", "copy-done", "after-all", "partition-id",
            "replica-id", "iota", "broadcast", "reshape", "transpose"}

_SHAPE = re.compile(r"\b([a-z]\w*?)\[([\d,]*)\]")
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_OP_NAME = re.compile(r"^(?:\([^)]*\)|\S+)\s+([\w\-]+)\(")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY = re.compile(r"body=%?([\w.\-]+)")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _first_paren_span(rhs: str) -> str:
    """Contents of the op's argument parens (operand list)."""
    start = rhs.find("(")
    if start < 0:
        return ""
    depth = 0
    for i in range(start, len(rhs)):
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
            if depth == 0:
                return rhs[start + 1:i]
    return rhs[start + 1:]


_OPERAND_REF = re.compile(r"%([\w.\-]+)")

# ops whose line-level operands/results are NOT HBM traffic (control flow /
# aliasing); their bodies' ops are counted instead.
NON_TRAFFIC = {"while", "conditional", "call", "custom-call", "fusion-marker"}


class HloCost:
    """Per-computation costs, expanded through loops / fusions / branches.

    Operands in post-optimization HLO are bare ``%name`` references; shapes
    are resolved through a per-computation name -> result-type map.
    """

    def __init__(self, hlo_text: str):
        self.comps: dict = {}
        self._parse(hlo_text)
        self._memo: dict = {}

    @staticmethod
    def _result_type(rhs: str, op: str) -> str:
        key = " " + op + "("
        idx = rhs.find(key)
        if idx >= 0:
            return rhs[:idx].strip()
        return rhs.split(op + "(")[0].strip()

    def _parse(self, text: str):
        cur = None
        entry = None
        # pass 1: collect op lines per computation + name->type map
        comp_lines: dict = {}
        types: dict = {}          # (comp, name) -> result type str
        for raw in text.splitlines():
            hm = _COMP_HEADER.match(raw)
            if hm and not raw.startswith(" "):
                cur = hm.group(1)
                comp_lines[cur] = []
                if raw.startswith("ENTRY"):
                    entry = cur
                continue
            if cur is None:
                continue
            om = _OP_LINE.match(raw)
            if not om:
                continue
            name, rhs = om.group(1), om.group(2)
            nm = _OP_NAME.match(rhs)
            if not nm:
                continue
            op = nm.group(1)
            rt = self._result_type(rhs, op)
            types[(cur, name)] = rt
            comp_lines[cur].append((name, op, rhs, rt))
        self.entry = entry

        # pass 2: cost each computation with resolved operand shapes
        for comp_name, rows in comp_lines.items():
            comp = {"flops": 0.0, "bytes": 0.0, "colls": [], "subs": []}
            self.comps[comp_name] = comp

            def operand_bytes(rhs):
                span = _first_paren_span(rhs)
                inline = shape_bytes(span)
                if inline:
                    return inline
                total = 0
                for ref in _OPERAND_REF.findall(span):
                    rt = types.get((comp_name, ref))
                    if rt:
                        total += shape_bytes(rt)
                return total

            for name, op, rhs, rt in rows:
                if op == "dot":
                    res = 1
                    m = _SHAPE.search(rt)
                    if m and m.group(2):
                        for d in m.group(2).split(","):
                            res *= int(d)
                    contract = 1
                    cm = _CONTRACT.search(rhs)
                    span = _first_paren_span(rhs)
                    refs = _OPERAND_REF.findall(span)
                    lhs_t = _SHAPE.search(span)  # typed operand if present
                    if lhs_t is None and refs:
                        lhs_rt = types.get((comp_name, refs[0]), "")
                        lhs_t = _SHAPE.search(lhs_rt)
                    if lhs_t and cm and cm.group(1):
                        dims = [int(d) for d in lhs_t.group(2).split(",")] \
                            if lhs_t.group(2) else []
                        for ci in cm.group(1).split(","):
                            ci = int(ci)
                            if ci < len(dims):
                                contract *= dims[ci]
                    comp["flops"] += 2.0 * res * contract

                if op == "while":
                    tm = _TRIP.search(rhs)
                    bm = _BODY.search(rhs)
                    trip = int(tm.group(1)) if tm else 1
                    if bm:
                        comp["subs"].append((bm.group(1), trip))
                elif op == "fusion":
                    cm = _CALLS.search(rhs)
                    if cm:
                        comp["subs"].append((cm.group(1), 1))
                elif op == "conditional":
                    brm = _BRANCHES.search(rhs)
                    if brm:
                        for b in brm.group(1).split(","):
                            comp["subs"].append((b.strip().lstrip("%"), 1))

                base = op.replace("-start", "").replace("-done", "")
                if base in COLLECTIVES and not op.endswith("-done"):
                    gsize = 0
                    gm = _GROUPS.search(rhs)
                    if gm:
                        gsize = len(gm.group(1).split(","))
                    else:
                        gi = _GROUPS_IOTA.search(rhs)
                        if gi:
                            gsize = int(gi.group(2))
                    comp["colls"].append((base, operand_bytes(rhs), gsize))

                if op in FREE_OPS or op in NON_TRAFFIC:
                    continue
                if op == "dynamic-update-slice":
                    # in-place: traffic is the updated slice (read+write),
                    # not the whole aliased buffer
                    span = _first_paren_span(rhs)
                    refs = _OPERAND_REF.findall(span)
                    upd = types.get((comp_name, refs[1]), "") if len(refs) > 1 else ""
                    comp["bytes"] += 2 * shape_bytes(upd)
                elif op == "dynamic-slice":
                    comp["bytes"] += 2 * shape_bytes(rt)
                else:
                    comp["bytes"] += operand_bytes(rhs) + shape_bytes(rt)

    def _expand(self, name: str):
        if name in self._memo:
            return self._memo[name]
        c = self.comps.get(name)
        if c is None:
            return 0.0, 0.0, {}
        self._memo[name] = (0.0, 0.0, {})  # cycle guard
        flops, byts = c["flops"], c["bytes"]
        colls: dict = {}
        for kind, b, gsize in c["colls"]:
            rec = colls.setdefault(kind, {"bytes": 0, "count": 0,
                                          "by_group_size": {}})
            rec["bytes"] += b
            rec["count"] += 1
            key = str(gsize)
            rec["by_group_size"][key] = rec["by_group_size"].get(key, 0) + b
        for sub, trip in c["subs"]:
            sf, sb, sc = self._expand(sub)
            flops += sf * trip
            byts += sb * trip
            for kind, rec in sc.items():
                dst = colls.setdefault(kind, {"bytes": 0, "count": 0,
                                              "by_group_size": {}})
                dst["bytes"] += rec["bytes"] * trip
                dst["count"] += rec["count"] * trip
                for gs, b in rec["by_group_size"].items():
                    dst["by_group_size"][gs] = dst["by_group_size"].get(gs, 0) \
                        + b * trip
        self._memo[name] = (flops, byts, colls)
        return self._memo[name]

    def totals(self) -> dict:
        flops, byts, colls = self._expand(self.entry)
        return {
            "flops": flops,
            "bytes": byts,
            "collectives": colls,
            "collective_bytes": sum(r["bytes"] for r in colls.values()),
        }


def analyze(hlo_text: str) -> dict:
    return HloCost(hlo_text).totals()


# kept for backward-compat with earlier callers/tests
def collective_stats(hlo_text: str) -> dict:
    return analyze(hlo_text)["collectives"]


def total_collective_bytes(stats: dict) -> int:
    return sum(rec["bytes"] for rec in stats.values())


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e per-chip constants)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link


def roofline_terms(per_device_flops: float, per_device_bytes: float,
                   per_device_coll_bytes: float) -> dict:
    """Seconds per step for each roofline term (per-device quantities in)."""
    compute_s = per_device_flops / PEAK_FLOPS
    memory_s = per_device_bytes / HBM_BW
    collective_s = per_device_coll_bytes / ICI_BW
    dominant = max(
        [("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)], key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "step_lower_bound_s": max(compute_s, memory_s, collective_s),
    }


def model_flops(n_params_active: int, tokens: int, kind: str) -> float:
    """6*N*D for training, 2*N*D for a forward-only serving step."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens
