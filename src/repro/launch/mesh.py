"""Production mesh construction.

Defined as functions (not module constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax init.

``make_mesh`` papers over the jax API skew: ``axis_types`` (and
``jax.sharding.AxisType``) only exist from jax 0.5; on older releases every
mesh axis is implicitly Auto, so the kwarg is simply dropped.
"""
from __future__ import annotations

from typing import Sequence

import jax
from jax.sharding import Mesh


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """Version-compatible ``jax.make_mesh`` with all-Auto axis types."""
    if hasattr(jax.sharding, "AxisType"):
        types = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(tuple(shape), tuple(axes), axis_types=types)
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Whatever this process actually has (tests / local runs)."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))


def mesh_devices(mesh: Mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
