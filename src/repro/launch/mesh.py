"""Production mesh construction.

Defined as functions (not module constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=types)


def make_host_mesh() -> Mesh:
    """Whatever this process actually has (tests / local runs)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def mesh_devices(mesh: Mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
