"""Serving launcher: build an FCVI index over a synthetic corpus and serve
batched filtered queries through the engine (caching, adaptive k',
escalation, live inserts).

    PYTHONPATH=src python -m repro.launch.serve --n 50000 --queries 512
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import FCVIConfig, build, ground_truth_combined, recall_at_k
from repro.data.synthetic import CorpusSpec, make_corpus, sample_queries
from repro.serve.engine import EngineConfig, FCVIEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50000)
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--backend", default="flat", choices=["flat", "ivf", "pq"])
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--lam", type=float, default=0.6)
    args = ap.parse_args()

    spec = CorpusSpec(n=args.n, d=args.d, n_categories=6, n_numeric=2, seed=0)
    corpus = make_corpus(spec)
    t0 = time.perf_counter()
    index = build(jnp.asarray(corpus.vectors), jnp.asarray(corpus.filters),
                  FCVIConfig(alpha=args.alpha, lam=args.lam, c=16.0,
                             backend=args.backend, nlist=128, nprobe=16))
    print(f"built fcvi-{args.backend} over {args.n} vectors "
          f"in {time.perf_counter()-t0:.1f}s")

    engine = FCVIEngine(index, EngineConfig(k=args.k, batch_size=64))
    q, fq = sample_queries(corpus, args.queries, seed=1)

    t0 = time.perf_counter()
    scores, ids = engine.search(q, fq)
    dt = time.perf_counter() - t0

    qn, fqn = index.transform.normalize(jnp.asarray(q), jnp.asarray(fq))
    _, ref = ground_truth_combined(index.vectors_n, index.filters_n, qn, fqn,
                                   args.k, args.lam)
    rec = float(recall_at_k(jnp.asarray(ids), ref))
    print(f"{args.queries} queries in {dt:.2f}s -> {args.queries/dt:.0f} qps, "
          f"recall@{args.k}={rec:.3f}")
    print(f"engine stats: {engine.stats.cache_hits} cache hits, "
          f"{engine.stats.escalations} escalations")

    # repeat -> cache hits
    t0 = time.perf_counter()
    engine.search(q[:128], fq[:128])
    print(f"cached re-serve of 128 queries: "
          f"{(time.perf_counter()-t0)*1e3:.0f}ms "
          f"({engine.stats.cache_hits} total cache hits)")


if __name__ == "__main__":
    main()
