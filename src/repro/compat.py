"""Small jax version-compat shims.

The repo targets the current jax API; these helpers keep it runnable on the
previous minor series too (e.g. 0.4.x, where ``shard_map`` still lives in
``jax.experimental`` and ``check_vma`` is spelled ``check_rep``).
"""
from __future__ import annotations

import jax


def axis_size(axis_name):
    """``jax.lax.axis_size`` with psum(1) fallback for older jax."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with fallback to ``jax.experimental.shard_map``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
