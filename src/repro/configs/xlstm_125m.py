"""xlstm-125m [arXiv:2405.04517]: 12L, d_model=768, 4 heads, head_dim=192,
no separate FFN (d_ff=0 — xLSTM blocks carry their own projections),
vocab=50304. Alternating mLSTM (matrix memory, chunkwise-parallel) and
sLSTM (scalar memory, sequential) blocks. Constant-size state =>
long_500k eligible."""
from repro.configs.base import register
from repro.models.model import ModelConfig


@register("xlstm-125m")
def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        head_dim=192,
        d_ff=0,
        vocab_size=50304,
        pattern=("mlstm", "slstm"),
        mlp_kind="none",
        pos_kind="none",
        lstm_chunk=128,
        sub_quadratic=True,
    )
