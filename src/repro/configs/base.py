"""Config registry: every assigned architecture is a selectable ``--arch``.

``get_config(name)`` returns the full published config; ``reduced(cfg)``
returns a family-preserving miniature (same pattern / same block kinds /
same MoE-ness) for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

from repro.models.model import ModelConfig

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list:
    return sorted(_REGISTRY)


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving miniature for CPU smoke tests."""
    period = cfg.period
    n_layers = max(period, 2 if period == 1 else period)
    changes = dict(
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        window=min(cfg.window, 32) if cfg.window else cfg.window,
        n_enc_layers=2 if cfg.enc_dec else 0,
        n_prefix=8 if cfg.n_prefix else 0,
        d_rnn=64 if cfg.d_rnn else 0,
        lstm_chunk=16,
        q_chunk=32,
        kv_chunk=32,
        remat=False,
    )
    if cfg.is_moe:
        changes.update(moe_experts=4, moe_top_k=2, moe_d_ff=64)
    return dataclasses.replace(cfg, **changes)
