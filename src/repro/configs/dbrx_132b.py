"""dbrx-132b [hf:databricks/dbrx-base]: MoE 40L, d_model=6144, 48 heads
(GQA kv=8), head_dim=128, vocab=100352, 16 experts top-4, d_ff=10752
per expert (GLU), rope_theta=5e5, fine-grained MoE."""
from repro.configs.base import register
from repro.models.model import ModelConfig


@register("dbrx-132b")
def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=10752,
        vocab_size=100352,
        pattern=("attn",),
        mlp_kind="swiglu",
        moe_experts=16,
        moe_top_k=4,
        moe_d_ff=10752,
        rope_theta=5e5,
        tie_embeddings=False,
        sub_quadratic=False,
    )
