"""granite-moe-3b-a800m [hf:ibm-granite]: MoE 32L, d_model=1536, 24 heads
(GQA kv=8), vocab=49155, 40 experts top-8, d_ff=512 per expert (SwiGLU)."""
from repro.configs.base import register
from repro.models.model import ModelConfig


@register("granite-moe-3b-a800m")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        head_dim=64,
        d_ff=512,
        vocab_size=49155,
        pattern=("attn",),
        mlp_kind="swiglu",
        moe_experts=40,
        moe_top_k=8,
        moe_d_ff=512,
        sub_quadratic=False,
    )
