"""gemma2-27b [arXiv:2408.00118]: dense 46L, d_model=4608, 32 heads
(GQA kv=16), head_dim=128, d_ff=36864 GeGLU, vocab=256000.

Alternating local(4096):global 1:1, attn logit softcap 50, final softcap 30,
pre+post RMSNorm per sub-block, embed scaled by sqrt(d)."""
from repro.configs.base import register
from repro.models.model import ModelConfig


@register("gemma2-27b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b",
        n_layers=46,
        d_model=4608,
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        d_ff=36864,
        vocab_size=256000,
        pattern=("local", "attn"),
        window=4096,
        mlp_kind="geglu",
        attn_softcap=50.0,
        final_softcap=30.0,
        post_norm=True,
        embed_scale=True,
        sub_quadratic=False,   # half the layers are full global attention
    )
