"""whisper-large-v3 [arXiv:2212.04356]: enc-dec audio, 32L enc + 32L dec,
d_model=1280, 20 heads (MHA: kv=20), d_ff=5120, vocab=51866.

Conv frontend is a STUB: input_specs() supplies precomputed (b, frames, 1280)
log-mel frame embeddings. Decoder has causal self-attn + cross-attn;
sinusoidal positions; pre-LN (whisper uses LayerNorm, GELU MLP).
"""
from repro.configs.base import register
from repro.models.model import ModelConfig


@register("whisper-large-v3")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        n_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        head_dim=64,
        d_ff=5120,
        vocab_size=51866,
        pattern=("attn",),
        mlp_kind="gelu",
        norm_kind="ln",
        pos_kind="sinusoidal",
        enc_dec=True,
        n_enc_layers=32,
        frontend="audio_stub",
        tie_embeddings=True,
        sub_quadratic=False,   # full-attention encoder: long_500k skipped
    )
