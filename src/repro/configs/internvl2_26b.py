"""internvl2-26b [arXiv:2404.16821]: VLM — InternViT frontend (STUB: patch
embeddings precomputed, n_prefix=1024) + InternLM2-20B backbone: 48L,
d_model=6144, 48 heads (GQA kv=8), head_dim=128, d_ff=16384 SwiGLU,
vocab=92553."""
from repro.configs.base import register
from repro.models.model import ModelConfig


@register("internvl2-26b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92553,
        pattern=("attn",),
        mlp_kind="swiglu",
        frontend="vision_stub",
        n_prefix=1024,
        tie_embeddings=False,
        sub_quadratic=False,
    )
