"""gemma3-1b [hf:google/gemma-3-1b-pt]: 26L, d_model=1152, 4 heads (GQA kv=1),
d_ff=6912 GeGLU, vocab=262144. 5:1 local:global (window 512), 128k-class.

26 = 4 periods of 6 (5 local + 1 global) + remainder (local, local).
long_500k RUNS: 5/6 of layers hold a 512-window KV; the few global layers
hold full-length KV (sequence-sharded over 'model')."""
from repro.configs.base import register
from repro.models.model import ModelConfig


@register("gemma3-1b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b",
        n_layers=26,
        d_model=1152,
        n_heads=4,
        n_kv_heads=1,
        head_dim=288,
        d_ff=6912,
        vocab_size=262144,
        pattern=("local", "local", "local", "local", "local", "attn"),
        window=512,
        mlp_kind="geglu",
        embed_scale=True,
        rope_theta=1e6,
        sub_quadratic=True,
    )
