"""starcoder2-7b [arXiv:2402.19173]: dense 32L, d_model=4608, 36 heads
(GQA kv=4), d_ff=18432, vocab=49152, RoPE, GELU MLP (starcoder2 uses
pre-LN + gelu; we keep LN to match)."""
from repro.configs.base import register
from repro.models.model import ModelConfig


@register("starcoder2-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        n_layers=32,
        d_model=4608,
        n_heads=36,
        n_kv_heads=4,
        head_dim=128,
        d_ff=18432,
        vocab_size=49152,
        pattern=("attn",),
        mlp_kind="gelu",
        norm_kind="ln",
        rope_theta=1e5,
        sub_quadratic=False,
    )
