"""recurrentgemma-2b (Griffin) [arXiv:2402.19427]: 26L, d_model=2560,
10 heads (GQA kv=1), d_ff=7680 GeGLU, vocab=256000.

Pattern: (rec, rec, local) — RG-LRU : local attention = 2 : 1, local window
2048. d_rnn = d_model. 26 = 8 periods of 3 + remainder (rec, rec).
"""
from repro.configs.base import register
from repro.models.model import ModelConfig


@register("recurrentgemma-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        pattern=("rec", "rec", "local"),
        window=2048,
        mlp_kind="geglu",
        embed_scale=True,
        d_rnn=2560,
        conv_width=4,
        sub_quadratic=True,    # O(1) recurrent state + windowed KV
    )
