"""Architecture configs — import side-effect registers every arch."""
from repro.configs.base import get_config, list_archs, reduced, register
from repro.configs import (whisper_large_v3, recurrentgemma_2b, starcoder2_7b,
                           gemma3_1b, mistral_nemo_12b, gemma2_27b,
                           granite_moe_3b, dbrx_132b, xlstm_125m,
                           internvl2_26b)

__all__ = ["get_config", "list_archs", "reduced", "register"]
