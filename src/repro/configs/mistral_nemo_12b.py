"""mistral-nemo-12b [hf:mistralai/Mistral-Nemo-Base-2407]: dense 40L,
d_model=5120, 32 heads (GQA kv=8), head_dim=128, d_ff=14336 SwiGLU,
vocab=131072, full attention (128k ctx), rope_theta=1e6."""
from repro.configs.base import register
from repro.models.model import ModelConfig


@register("mistral-nemo-12b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        pattern=("attn",),
        mlp_kind="swiglu",
        rope_theta=1e6,
        tie_embeddings=False,
        sub_quadratic=False,
    )
