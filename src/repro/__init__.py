"""repro — FCVI (Filter-Centric Vector Indexing) as a multi-pod JAX framework."""
__version__ = "0.1.0"
