"""Composable filter algebra over the index's attribute columns.

FCVI folds ONE filter vector through psi; real workloads filter on
predicates: ranges, equalities, categorical IN-lists, and conjunctions of
those over several attribute columns. This module is the predicate
*language* and its compiler; `repro.serve.planner` picks the physical
execution plan (psi fold / in-kernel mask / routed pruning) per query.

User surface (attribute columns are referred to by name)::

    from repro.core.filters import F
    pred = F.range("price", 10, 50) & F.isin("region", [2, 5])
    engine.search(queries, filter=pred)

Compilation (``compile_predicate``) lowers any predicate tree to ONE
fixed-shape :class:`CompiledPredicate`: per-column ``[lo, hi]`` interval
bounds plus a padded IN-list table (``MAX_ISIN`` slots — a static shape, so
every predicate shares one jit trace per physical plan). Conjunctions merge
by interval intersection / IN-list intersection; an empty intersection
compiles to the always-false interval ``[+inf, -inf]``.

Evaluation semantics are defined over the engine's fp32 attribute table and
are PURE ELEMENTWISE comparisons — no accumulation, no dtype-dependent
rounding — so the numpy oracle (``CompiledPredicate.eval_np``), the traced
jnp evaluation (``eval_mask``), and the in-kernel mask operand agree
bit-for-bit on every row. NaN attribute entries (the padding sentinel used
by the sharded slabs) compare false on every bound, so padding rows are
never eligible.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

#: Static width of the compiled IN-list table. Keeping this a module
#: constant (not a per-predicate shape) is what lets every predicate share
#: one trace per physical plan — the planner's jit-key discipline.
MAX_ISIN = 16


# ---------------------------------------------------------------------------
# The algebra (user-facing predicate trees)
# ---------------------------------------------------------------------------

class Predicate:
    """Base of the filter algebra; ``&`` builds conjunctions."""

    def __and__(self, other: "Predicate") -> "Predicate":
        if not isinstance(other, Predicate):
            return NotImplemented
        mine = self.children if isinstance(self, And) else (self,)
        theirs = other.children if isinstance(other, And) else (other,)
        return And(mine + theirs)


@dataclasses.dataclass(frozen=True)
class Range(Predicate):
    """``lo <= attr <= hi`` (either bound may be None = unbounded)."""

    attr: str
    lo: Optional[float]
    hi: Optional[float]


@dataclasses.dataclass(frozen=True)
class Eq(Predicate):
    """``attr == value`` (compiled as a one-element IN-list)."""

    attr: str
    value: float


@dataclasses.dataclass(frozen=True)
class IsIn(Predicate):
    """``attr in values`` (categorical membership, <= MAX_ISIN values)."""

    attr: str
    values: Tuple[float, ...]


@dataclasses.dataclass(frozen=True)
class And(Predicate):
    """Conjunction over any mix of leaves (flattened by ``&``)."""

    children: Tuple[Predicate, ...]


class F:
    """Constructor namespace: ``F.range(...) & F.isin(...) & F.eq(...)``."""

    @staticmethod
    def range(attr: str, lo: Optional[float] = None,
              hi: Optional[float] = None) -> Range:
        return Range(attr, lo, hi)

    @staticmethod
    def eq(attr: str, value: float) -> Eq:
        return Eq(attr, float(value))

    @staticmethod
    def isin(attr: str, values: Sequence[float]) -> IsIn:
        vals = tuple(float(v) for v in values)
        if not vals:
            raise ValueError("isin() needs at least one value")
        if len(vals) > MAX_ISIN:
            raise ValueError(
                f"isin() supports at most {MAX_ISIN} values, got {len(vals)}")
        return IsIn(attr, vals)


# ---------------------------------------------------------------------------
# Compilation: predicate tree -> fixed-shape column constraints
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CompiledPredicate:
    """A predicate lowered to per-column fp32 constraint arrays.

    ``lo``/``hi``: (m,) interval bounds (-inf/+inf = unconstrained; an empty
    conjunction compiles to the always-false ``[+inf, -inf]``).
    ``isin_vals``: (m, MAX_ISIN) padded membership table, ``isin_count``:
    (m,) live slots (0 = no IN constraint on that column). ``constrained``
    names the columns any leaf touches (the planner's selectivity inputs).
    """

    lo: np.ndarray
    hi: np.ndarray
    isin_vals: np.ndarray
    isin_count: np.ndarray
    constrained: Tuple[int, ...]

    @property
    def n_attrs(self) -> int:
        return int(self.lo.shape[0])

    def as_arrays(self):
        """The four constraint arrays as jnp data operands for traced steps."""
        return (jnp.asarray(self.lo), jnp.asarray(self.hi),
                jnp.asarray(self.isin_vals), jnp.asarray(self.isin_count))

    def eval_np(self, attrs) -> np.ndarray:
        """(n,) bool eligibility over a raw fp32 attribute table (numpy
        brute-force oracle; bit-identical to the traced ``eval_mask``)."""
        a = np.asarray(attrs, np.float32)
        ok = (a >= self.lo[None, :]) & (a <= self.hi[None, :])
        has = self.isin_count[None, :] > 0
        hit = a[:, :, None] == self.isin_vals[None, :, :]
        hit = hit & (np.arange(MAX_ISIN)[None, None, :]
                     < self.isin_count[None, :, None])
        ok = ok & np.where(has, hit.any(-1), True)
        return ok.all(-1)

    def fold_target_raw(self, col_means) -> np.ndarray:
        """(m,) raw-space filter query vector for the psi fold: constrained
        columns fold to their constraint's representative value (interval
        midpoint / finite bound / IN-list mean), unconstrained columns to the
        corpus column mean (whose normalized image is 0 — no pull)."""
        t = np.asarray(col_means, np.float32).copy()
        for j in range(self.n_attrs):
            c = int(self.isin_count[j])
            if c > 0:
                t[j] = np.float32(np.mean(self.isin_vals[j, :c]))
                continue
            lo, hi = float(self.lo[j]), float(self.hi[j])
            if np.isfinite(lo) and np.isfinite(hi):
                t[j] = np.float32(0.5 * (lo + hi))
            elif np.isfinite(lo):
                t[j] = np.float32(lo)
            elif np.isfinite(hi):
                t[j] = np.float32(hi)
        return t


def eval_mask(attrs, lo, hi, isin_vals, isin_count):
    """Traced (n,) bool eligibility — same elementwise ops as ``eval_np``.

    ``attrs`` may be any (..., m) fp32 table (flat rows or the IVF grouped
    layout); the mask shape follows. NaN entries are never eligible.
    """
    a = attrs.astype(jnp.float32)
    ok = (a >= lo) & (a <= hi)
    has = isin_count > 0
    hit = a[..., None] == isin_vals
    hit = hit & (jnp.arange(MAX_ISIN) < isin_count[..., None])
    ok = ok & jnp.where(has, hit.any(-1), True)
    return ok.all(-1)


def compile_predicate(pred: Predicate,
                      attr_names: Sequence[str]) -> CompiledPredicate:
    """Lower a predicate tree onto the index's attribute schema.

    ``attr_names`` maps column order to names; unknown attribute names are a
    ValueError (they would otherwise silently match nothing).
    """
    if isinstance(pred, CompiledPredicate):
        return pred
    col: Dict[str, int] = {n: i for i, n in enumerate(attr_names)}
    m = len(attr_names)
    lo = np.full((m,), -np.inf, np.float32)
    hi = np.full((m,), np.inf, np.float32)
    isin_vals = np.zeros((m, MAX_ISIN), np.float32)
    isin_count = np.zeros((m,), np.int32)
    isin_sets: Dict[int, set] = {}
    touched = set()

    def leaf_col(attr: str) -> int:
        if attr not in col:
            raise ValueError(
                f"unknown attribute {attr!r}; index has {tuple(col)}")
        touched.add(col[attr])
        return col[attr]

    def walk(p: Predicate):
        if isinstance(p, And):
            for c in p.children:
                walk(c)
        elif isinstance(p, Range):
            j = leaf_col(p.attr)
            if p.lo is not None:
                lo[j] = max(lo[j], np.float32(p.lo))
            if p.hi is not None:
                hi[j] = min(hi[j], np.float32(p.hi))
        elif isinstance(p, (Eq, IsIn)):
            j = leaf_col(p.attr)
            vals = {np.float32(p.value)} if isinstance(p, Eq) else \
                {np.float32(v) for v in p.values}
            if j in isin_sets:
                isin_sets[j] &= vals
            else:
                isin_sets[j] = set(vals)
        else:
            raise TypeError(f"not a predicate: {p!r}")

    walk(pred)
    for j, vals in isin_sets.items():
        if not vals:
            # empty IN-list intersection: compile to the always-false interval
            lo[j], hi[j] = np.float32(np.inf), np.float32(-np.inf)
            continue
        ordered = sorted(vals)
        if len(ordered) > MAX_ISIN:
            raise ValueError(
                f"IN-list on column {j} has {len(ordered)} values; the "
                f"compiled table holds at most {MAX_ISIN}")
        isin_count[j] = len(ordered)
        isin_vals[j, :len(ordered)] = np.asarray(ordered, np.float32)
    return CompiledPredicate(lo=lo, hi=hi, isin_vals=isin_vals,
                             isin_count=isin_count,
                             constrained=tuple(sorted(touched)))
