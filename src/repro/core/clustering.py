"""Deterministic fixed-iteration k-means in pure JAX.

Used by the cluster-based psi transform (Eq. 6), the IVF coarse quantizer and
PQ codebook training. Fixed iteration count + kmeans++-style seeding keeps the
computation SPMD-friendly (no dynamic convergence loop) and bitwise
reproducible from the PRNG key.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array


def _pairwise_sq_dists(x: Array, c: Array) -> Array:
    """(n, d) x (k, d) -> (n, k) squared Euclidean distances (clamped >= 0)."""
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)
    return jnp.maximum(x2 - 2.0 * (x @ c.T) + c2, 0.0)


def kmeans_plus_plus_init(rng: Array, x: Array, k: int) -> Array:
    """k-means++ seeding (vectorised, O(k n d))."""
    n = x.shape[0]
    first = jax.random.randint(rng, (), 0, n)
    centers0 = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])

    def body(i, state):
        centers, key = state
        key, sub = jax.random.split(key)
        d2 = _pairwise_sq_dists(x, centers)
        # distance to nearest already-chosen center; unchosen slots are zero
        # vectors — mask them out by only considering slots < i.
        mask = jnp.arange(k) < i
        d2 = jnp.where(mask[None, :], d2, jnp.inf)
        dmin = jnp.min(d2, axis=-1)
        probs = dmin / jnp.maximum(jnp.sum(dmin), 1e-30)
        idx = jax.random.choice(sub, n, p=probs)
        return centers.at[i].set(x[idx]), key

    centers, _ = jax.lax.fori_loop(1, k, body, (centers0, rng))
    return centers


@partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(rng: Array, x: Array, k: int, iters: int = 25) -> tuple[Array, Array]:
    """Lloyd's with kmeans++ init. Returns (centers (k,d), labels (n,))."""
    x = jnp.asarray(x, jnp.float32)
    centers = kmeans_plus_plus_init(rng, x, k)

    def step(centers, _):
        d2 = _pairwise_sq_dists(x, centers)
        labels = jnp.argmin(d2, axis=-1)
        one_hot = jax.nn.one_hot(labels, k, dtype=x.dtype)  # (n, k)
        counts = jnp.sum(one_hot, axis=0)                    # (k,)
        sums = one_hot.T @ x                                 # (k, d)
        new = sums / jnp.maximum(counts[:, None], 1.0)
        # keep old center for empty clusters
        new = jnp.where(counts[:, None] > 0, new, centers)
        return new, None

    centers, _ = jax.lax.scan(step, centers, None, length=iters)
    labels = jnp.argmin(_pairwise_sq_dists(x, centers), axis=-1)
    return centers, labels


def assign(x: Array, centers: Array) -> Array:
    """Nearest-center assignment."""
    return jnp.argmin(_pairwise_sq_dists(x, centers), axis=-1)


def quantization_error(x: Array, centers: Array) -> Array:
    return jnp.mean(jnp.min(_pairwise_sq_dists(x, centers), axis=-1))
