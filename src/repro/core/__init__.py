"""FCVI core — the paper's contribution (transform + unified index + query)."""
from repro.core.transform import (
    Normalizer,
    Transform,
    fit_transform,
    psi_partition,
    psi_cluster,
    psi_embedding,
    tiled_filter,
)
from repro.core.fcvi import (
    FCVIConfig,
    FCVIIndex,
    build,
    query,
    multi_probe_query,
    ground_truth_combined,
    recall_at_k,
    extend,
    cosine_sim,
)
from repro.core.baselines import (
    BoxPredicate,
    post_filter_search,
    pre_filter_search,
    build_hybrid,
    hybrid_search,
    ground_truth_filtered,
)
from repro.core import theory

__all__ = [
    "Normalizer", "Transform", "fit_transform", "psi_partition", "psi_cluster",
    "psi_embedding", "tiled_filter", "FCVIConfig", "FCVIIndex", "build",
    "query", "multi_probe_query", "ground_truth_combined", "recall_at_k",
    "extend", "cosine_sim", "BoxPredicate", "post_filter_search",
    "pre_filter_search", "build_hybrid", "hybrid_search",
    "ground_truth_filtered", "theory",
]
