"""FCVI geometric transformations (paper §4.1).

The transformation family psi(v, f, alpha) folds a filter vector f in R^m into
an embedding v in R^d (m <= d) without changing dimensionality:

  * partition  (Eq. 5): split v into d/m segments, subtract alpha*f from each.
  * cluster    (Eq. 6): subtract alpha * (k-means center of f) instead — robust
                        to high-cardinality / noisy filters.
  * embedding  (Eq. 7): v - alpha * W f with a learned projection W in R^{d x m}.

All functions are pure, jit-able, and batched over leading axes.
The paper (§3.1, Eq. 1-2) requires each dimension of v and f to be
standardized to N(0,1) across the dataset; ``Normalizer`` implements that.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Per-dimension standardization (paper Eq. 1-2)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Normalizer:
    """Per-dimension affine standardizer: x -> (x - mean) / std."""

    mean: Array  # (dim,)
    std: Array   # (dim,)

    def tree_flatten(self):
        return (self.mean, self.std), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @staticmethod
    def fit(x: Array, eps: float = 1e-6) -> "Normalizer":
        """Fit over all leading axes; ``x`` has shape (..., dim)."""
        flat = x.reshape(-1, x.shape[-1])
        mean = jnp.mean(flat, axis=0)
        std = jnp.std(flat, axis=0) + eps
        return Normalizer(mean=mean, std=std)

    def apply(self, x: Array) -> Array:
        return (x - self.mean) / self.std

    def inverse(self, x: Array) -> Array:
        return x * self.std + self.mean

    @staticmethod
    def identity(dim: int, dtype=jnp.float32) -> "Normalizer":
        return Normalizer(mean=jnp.zeros((dim,), dtype), std=jnp.ones((dim,), dtype))


# ---------------------------------------------------------------------------
# psi variants
# ---------------------------------------------------------------------------

def check_partition(d: int, m: int) -> int:
    if m <= 0 or d <= 0:
        raise ValueError(f"dims must be positive, got d={d} m={m}")
    if m > d:
        raise ValueError(f"filter dim m={m} must be <= vector dim d={d}")
    if d % m != 0:
        raise ValueError(
            f"partition transform needs d % m == 0, got d={d}, m={m}; "
            "pad the filter (Normalizer handles constant dims) or use the "
            "embedding transform"
        )
    return d // m


def psi_partition(v: Array, f: Array, alpha: float | Array) -> Array:
    """Eq. 5: psi(v,f,a) = [v^(1) - a f, ..., v^(d/m) - a f].

    v: (..., d); f: (..., m) with d % m == 0. Returns (..., d).
    """
    d, m = v.shape[-1], f.shape[-1]
    segs = check_partition(d, m)
    vt = v.reshape(*v.shape[:-1], segs, m)
    out = vt - alpha * f[..., None, :]
    return out.reshape(*v.shape)


def psi_partition_inverse(v_t: Array, f: Array, alpha: float | Array) -> Array:
    """Exact inverse of ``psi_partition`` given the filter (used by updates)."""
    d, m = v_t.shape[-1], f.shape[-1]
    segs = check_partition(d, m)
    vt = v_t.reshape(*v_t.shape[:-1], segs, m)
    return (vt + alpha * f[..., None, :]).reshape(*v_t.shape)


def nearest_center(f: Array, centers: Array) -> Array:
    """Substitute each filter with its nearest k-means center (squared L2)."""
    d2 = (
        jnp.sum(f * f, axis=-1, keepdims=True)
        - 2.0 * f @ centers.T
        + jnp.sum(centers * centers, axis=-1)
    )
    assign = jnp.argmin(d2, axis=-1)
    return centers[assign]


def psi_cluster(v: Array, f: Array, alpha: float | Array, centers: Array) -> Array:
    """Eq. 6: like Eq. 5 but subtract the nearest k-means center of f.

    centers: (n_clusters, m).
    """
    return psi_partition(v, nearest_center(f, centers), alpha)


def psi_embedding(v: Array, f: Array, alpha: float | Array, w: Array) -> Array:
    """Eq. 7: psi(v,f,a) = v - a * W f with W in R^{d x m}."""
    return v - alpha * (f @ w.T)


def tiled_filter(f: Array, d: int) -> Array:
    """Tile f to length d (the implicit 'filter direction' of psi_partition).

    psi_partition(v,f,a) == v - a * tiled_filter(f, d): subtracting f from
    every m-segment equals subtracting the d-dim tiling of f.
    """
    m = f.shape[-1]
    segs = check_partition(d, m)
    return jnp.tile(f, (*([1] * (f.ndim - 1)), segs))


# ---------------------------------------------------------------------------
# Transform spec — a pytree carrying the mode + fitted parameters
# ---------------------------------------------------------------------------

MODES = ("partition", "cluster", "embedding")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Transform:
    """Fitted FCVI transform: mode + alpha + normalizers (+ centers / W)."""

    mode: str  # static
    alpha: Array  # scalar
    vec_norm: Normalizer
    filt_norm: Normalizer
    centers: Optional[Array] = None   # (n_clusters, m) for mode=cluster
    proj: Optional[Array] = None      # (d, m) for mode=embedding

    def tree_flatten(self):
        children = (self.alpha, self.vec_norm, self.filt_norm, self.centers, self.proj)
        return children, self.mode

    @classmethod
    def tree_unflatten(cls, mode, children):
        alpha, vec_norm, filt_norm, centers, proj = children
        return cls(mode, alpha, vec_norm, filt_norm, centers, proj)

    # -- application ------------------------------------------------------
    def normalize(self, v: Array, f: Array) -> tuple[Array, Array]:
        return self.vec_norm.apply(v), self.filt_norm.apply(f)

    def projection(self) -> Array:
        """The (m, d) fold matrix P with psi(v, f, a) == v - a * (f @ P).

        partition/cluster fold via the 0/1 tiling matrix (exact: each output
        dim sums exactly one nonzero term); embedding folds via W^T. This is
        what lets all three psi variants share the single fused kernel.
        """
        from repro.kernels.ref import partition_matrix

        d = self.vec_norm.mean.shape[-1]
        m = self.filt_norm.mean.shape[-1]
        if self.mode == "embedding":
            assert self.proj is not None
            return self.proj.T
        return partition_matrix(d, m, self.vec_norm.mean.dtype)

    def _fused(self, v: Array, f: Array, vec_norm: "Normalizer",
               filt_norm: "Normalizer") -> Array:
        """One-kernel normalize+project+subtract over flattened rows."""
        from repro.kernels import ops

        d, m = v.shape[-1], f.shape[-1]
        out = ops.fused_transform(
            v.reshape(-1, d), f.reshape(-1, m), self.projection(), self.alpha,
            vec_norm.mean, vec_norm.std, filt_norm.mean, filt_norm.std)
        return out.reshape(*v.shape[:-1], d)

    def apply(self, v: Array, f: Array, *, use_pallas: bool = False) -> Array:
        """Normalize then transform RAW inputs: psi(norm(v), norm(f), alpha).

        v: (..., d) fp32 raw vectors; f: (..., m) fp32 raw filter values
        (any leading batch axes). Returns (..., d) fp32 transformed vectors
        — the space every search backend indexes.

        ``use_pallas=False`` (default) runs the jnp reference chain;
        ``True`` runs the whole chain — per-dim standardize of v and f,
        filter fold, subtract — as ONE fused kernel (``ops.fused_transform``)
        instead of 4+ jnp ops (cluster mode substitutes centers first, then
        fuses the rest). Both paths return identical values.
        """
        if not use_pallas:
            vn, fn = self.normalize(v, f)
            return self.apply_normalized(vn, fn)
        if self.mode == "cluster":
            assert self.centers is not None
            # center substitution is data-dependent, not affine: normalize
            # the filter outside, substitute, feed the kernel an identity
            # filter normalizer
            mu = nearest_center(self.filt_norm.apply(f), self.centers)
            return self._fused(v, mu, self.vec_norm,
                               Normalizer.identity(mu.shape[-1], mu.dtype))
        return self._fused(v, f, self.vec_norm, self.filt_norm)

    def apply_normalized(self, vn: Array, fn: Array, *,
                         use_pallas: bool = False) -> Array:
        """psi on ALREADY-normalized inputs (the hot-path entry point: the
        engine normalizes once and reuses vn/fn for re-ranking).

        vn: (..., d) fp32 standardized vectors; fn: (..., m) fp32
        standardized filters. Returns (..., d) fp32. ``use_pallas`` selects
        the fused kernel (identity normalizers are passed so the kernel
        only folds + subtracts) vs the jnp per-mode reference; identical
        results either way.
        """
        if use_pallas:
            f_in = fn
            if self.mode == "cluster":
                assert self.centers is not None
                f_in = nearest_center(fn, self.centers)
            return self._fused(
                vn, f_in,
                Normalizer.identity(vn.shape[-1], vn.dtype),
                Normalizer.identity(f_in.shape[-1], f_in.dtype))
        if self.mode == "partition":
            return psi_partition(vn, fn, self.alpha)
        if self.mode == "cluster":
            assert self.centers is not None
            return psi_cluster(vn, fn, self.alpha, self.centers)
        if self.mode == "embedding":
            assert self.proj is not None
            return psi_embedding(vn, fn, self.alpha, self.proj)
        raise ValueError(f"unknown transform mode {self.mode!r}")

    def fold_query(self, q_raw: Array, fold_raw: Array, *,
                   use_pallas: bool = False) -> Array:
        """Transform RAW queries against a RAW-space fold target.

        Predicate search has no per-query filter vector; instead the planner
        derives one representative point per predicate (``fold_target_raw``:
        interval midpoints / IN-list means, unconstrained dims at the column
        mean). Folding every query against that single target puts all
        candidates for the predicate into one consistent transformed frame.

        q_raw: (..., d) raw queries; fold_raw: (m,) raw filter-space target.
        Returns psi(norm(q), norm(fold), alpha) with the target broadcast
        across the batch.
        """
        fold = jnp.broadcast_to(
            jnp.asarray(fold_raw, q_raw.dtype),
            (*q_raw.shape[:-1], fold_raw.shape[-1]))
        qn, fn = self.normalize(q_raw, fold)
        return self.apply_normalized(qn, fn, use_pallas=use_pallas)


def fit_transform(
    vectors: Array,
    filters: Array,
    alpha: float,
    mode: str = "partition",
    *,
    n_clusters: int = 0,
    proj: Optional[Array] = None,
    rng: Optional[Array] = None,
    normalize: bool = True,
) -> Transform:
    """Fit normalizers (and cluster centers) on the corpus; return Transform."""
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    d, m = vectors.shape[-1], filters.shape[-1]
    if mode != "embedding":
        check_partition(d, m)
    if normalize:
        vec_norm = Normalizer.fit(vectors)
        filt_norm = Normalizer.fit(filters)
    else:
        vec_norm = Normalizer.identity(d, vectors.dtype)
        filt_norm = Normalizer.identity(m, filters.dtype)

    centers = None
    if mode == "cluster":
        from repro.core.clustering import kmeans

        if n_clusters <= 0:
            raise ValueError("cluster mode needs n_clusters > 0")
        if rng is None:
            rng = jax.random.PRNGKey(0)
        centers, _ = kmeans(rng, filt_norm.apply(filters), n_clusters)

    w = None
    if mode == "embedding":
        if proj is None:
            # default untrained projection: tile the identity so that the
            # embedding transform reduces to the partition transform; a
            # trained W can be supplied by repro.train.filter_proj.
            segs = d // m if d % m == 0 else 0
            if segs:
                w = jnp.tile(jnp.eye(m, dtype=vectors.dtype), (segs, 1))
            else:
                raise ValueError("embedding mode with d % m != 0 requires proj")
        else:
            w = jnp.asarray(proj)
            if w.shape != (d, m):
                raise ValueError(f"proj must be (d={d}, m={m}), got {w.shape}")

    return Transform(
        mode=mode,
        alpha=jnp.asarray(alpha, jnp.float32),
        vec_norm=vec_norm,
        filt_norm=filt_norm,
        centers=centers,
        proj=w,
    )
