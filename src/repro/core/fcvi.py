"""FCVIIndex — the paper's Algorithm 1 as a composable JAX module.

Offline: fit per-dim normalizers, fit psi (partition / cluster / embedding),
transform the corpus, build ANY backend index (flat / IVF / PQ) over the
transformed vectors, keep the normalized originals for re-scoring.

Online: transform the query with its filter vector, over-retrieve
k' = min(c * k/lambda * 1/alpha^2, N) (Thm 5.4), re-score candidates with
score = lambda*sim(v,q) + (1-lambda)*sim(f,F_q), return top-k.

Kernel-backed dispatch: every backend implements the
``repro.index.SearchBackend`` protocol, and ``FCVIConfig.use_pallas``
threads through the whole query path —

  * the query transform runs as ONE fused kernel
    (``Transform.apply_normalized(..., use_pallas=True)`` ->
    ``ops.fused_transform``) instead of 4+ jnp ops,
  * candidate generation runs the fused Pallas kernels
    (``ops.score_topk_padded`` / ``ops.ivf_score_topk_dedup`` over
    batch-deduplicated probes / ``ops.pq_score_batch``) instead of the
    pure-jnp scans, with the IVF coarse quantizer itself a small
    ``ops.score_topk_padded`` call,
  * re-scoring (``rescore`` and ``multi_probe_query``) runs the fused
    combined-cosine kernel ``ops.rescore``.

``FCVIConfig.storage_dtype`` additionally selects the flat/IVF slab storage
rung: "bfloat16" stores at half width for ~2x effective HBM bandwidth on the
scan-bound paths, "int8" at quarter width with one fp32 dequant scale per
row (``repro.index.quant``). Both keep fp32 accumulation plus the
exact-refine pass, so orderings stay correct.

With ``use_pallas=False`` (the default) the same call graph runs the jnp
reference implementations; the two paths return identical results (see
``tests/test_parity_pallas.py``), so the switch is a pure performance knob
that can be A/B-checked per call site.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import theory
from repro.core.transform import Transform, fit_transform
from repro.index import flat as flat_mod
from repro.index import ivf as ivf_mod
from repro.index import pq as pq_mod
from repro.index.backend import SearchBackend
from repro.kernels import ops

Array = jax.Array

BACKENDS = ("flat", "ivf", "pq")


@dataclasses.dataclass(frozen=True)
class FCVIConfig:
    """Static configuration of an FCVI index (hashable; the jit-static aux
    of the ``FCVIIndex`` pytree).

    Semantics-bearing fields: ``alpha`` (filter fold strength — larger
    separates filter groups harder), ``lam`` (combined-score weight),
    ``c`` (k' over-retrieval headroom), ``mode`` (psi variant), ``backend``
    + its shape knobs (``n_clusters``/``nlist``/``nprobe``/``pq_*``).

    Dispatch-changing fields (results stay IDENTICAL, only the executed
    code changes): ``use_pallas`` routes the query path through the Pallas
    kernels in ``repro.kernels.ops`` (False = pure-jnp reference), and
    ``storage_dtype`` selects the corpus slab precision ("float32",
    "bfloat16" or "int8"; reduced storage keeps fp32 norms/accumulation
    plus the exact-refine pass, so top-k ordering is exact w.r.t. stored
    rows — int8 additionally carries one fp32 scale per row).
    """

    alpha: float = 1.0
    lam: float = 0.5            # lambda in [0,1]: 1 => pure vector similarity
    c: float = 4.0              # k' headroom constant (Alg. 1 line 7)
    mode: str = "partition"     # psi variant
    backend: str = "flat"
    n_clusters: int = 16        # cluster mode
    nlist: int = 64             # IVF
    nprobe: int = 8
    pq_m: int = 8               # PQ subspaces
    pq_ksub: int = 256
    pq_coarse: int = 32         # residual-PQ coarse centers
    auto_alpha: bool = False    # alpha = max(1, sqrt((1-lam)/lam)), Thm 5.4
    normalize: bool = True
    use_pallas: bool = False    # route the query path through Pallas kernels
    storage_dtype: str = "float32"  # corpus storage for flat/IVF slabs
                                    # ("bfloat16" halves HBM traffic, "int8"
                                    # quarters it with per-row scales; scores
                                    # accumulate in fp32 and the exact-refine
                                    # pass keeps top-k ordering correct)
    def resolved_alpha(self) -> float:
        if self.auto_alpha:
            return float(theory.optimal_alpha(self.lam))
        return max(1.0, float(self.alpha))

    def resolved_storage_dtype(self):
        """Backend build-time dtype: None means keep the native fp32 (the
        backends' "don't cast" sentinel), else the reduced-precision dtype."""
        if self.storage_dtype not in ("float32", "bfloat16", "int8"):
            raise ValueError(
                f"storage_dtype must be float32, bfloat16 or int8, got "
                f"{self.storage_dtype!r}")
        if self.storage_dtype == "float32":
            return None
        return jnp.dtype(self.storage_dtype)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FCVIIndex:
    config: FCVIConfig          # static
    transform: Transform
    backend: SearchBackend      # FlatIndex | IVFIndex | PQIndex (transformed space)
    vectors_n: Array            # (n, d) normalized originals (for re-scoring)
    filters_n: Array            # (n, m) normalized filters

    def tree_flatten(self):
        return (self.transform, self.backend, self.vectors_n, self.filters_n), self.config

    @classmethod
    def tree_unflatten(cls, config, children):
        return cls(config, *children)

    @property
    def size(self) -> int:
        return self.vectors_n.shape[0]


def cosine_sim(a: Array, b: Array, eps: float = 1e-8) -> Array:
    num = jnp.sum(a * b, axis=-1)
    den = jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(b, axis=-1) + eps
    return num / den


def build(vectors: Array, filters: Array, config: FCVIConfig,
          rng: Optional[Array] = None) -> FCVIIndex:
    """Offline indexing (Alg. 1 lines 1-5)."""
    if config.backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}")
    if rng is None:
        rng = jax.random.PRNGKey(0)
    alpha = config.resolved_alpha()
    tfm = fit_transform(
        vectors, filters, alpha, config.mode,
        n_clusters=config.n_clusters, rng=rng, normalize=config.normalize,
    )
    vn = tfm.vec_norm.apply(vectors)
    fn = tfm.filt_norm.apply(filters)
    transformed = tfm.apply_normalized(vn, fn)

    backend = build_backend(transformed, config, rng=rng)
    assert isinstance(backend, SearchBackend)
    return FCVIIndex(config=config, transform=tfm, backend=backend,
                     vectors_n=vn, filters_n=fn)


def build_backend(transformed: Array, config: FCVIConfig,
                  rng: Optional[Array] = None) -> SearchBackend:
    """Build the configured backend over transformed vectors, with the
    configured storage dtype threaded into the flat/IVF slab layouts (PQ
    stores quantized codes already, so the knob does not apply there)."""
    st = config.resolved_storage_dtype()
    if config.backend == "flat":
        return flat_mod.build(transformed, storage_dtype=st)
    if config.backend == "ivf":
        return ivf_mod.build(transformed, nlist=config.nlist, rng=rng,
                             storage_dtype=st)
    return pq_mod.build(transformed, m_subspaces=config.pq_m,
                        ksub=config.pq_ksub, ncoarse=config.pq_coarse,
                        rng=rng)


def _backend_search(index: FCVIIndex, q_t: Array, kp: int):
    cfg = index.config
    if cfg.backend == "ivf":
        return index.backend.search(q_t, kp, use_pallas=cfg.use_pallas,
                                    nprobe=cfg.nprobe)
    return index.backend.search(q_t, kp, use_pallas=cfg.use_pallas)


def _pad_rows(x: Array, mult: int) -> Array:
    pad = -x.shape[0] % mult
    if not pad:
        return x
    return jnp.concatenate(
        [x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], axis=0)


def combined_score(cand_v: Array, cand_f: Array, qn: Array, fqn: Array,
                   lam, *, use_pallas: bool = False) -> Array:
    """score = lam*cos(v, q) + (1-lam)*cos(f, F_q) per candidate.

    cand_v: (b, kp, d); cand_f: (b, kp, m); qn: (b, d); fqn: (b, m).
    With ``use_pallas`` the fused re-ranking kernel computes both cosines and
    the affine combine in one VMEM pass (batch zero-padded to the kernel's
    block multiple; zero rows score 0 and are sliced off).
    """
    if not use_pallas:
        # Bit-stability contract: every serving path (single-device gather,
        # one-hot psum gather, shard-local gather in the gather-free step)
        # must feed this function GATHER-PRODUCED candidate tiles.  The
        # elementwise mul+sum cosine reduces each row independently, so a
        # candidate scores to the same bits regardless of its k-position in
        # the tile — unlike a dot_general contraction, whose CPU lowering
        # handles main-loop vs remainder k-rows differently.  Gather outputs
        # are materialized, so the reduction cannot fuse into a
        # path-dependent producer and reorder the sum.
        s_v = cosine_sim(cand_v, qn[:, None, :])
        s_f = cosine_sim(cand_f, fqn[:, None, :])
        return lam * s_v + (1.0 - lam) * s_f
    b = cand_v.shape[0]
    bb = min(8, b)
    s = ops.rescore(_pad_rows(cand_v, bb), _pad_rows(cand_f, bb),
                    _pad_rows(qn, bb), _pad_rows(fqn, bb), lam, block_b=bb)
    return s[:b]


def rescore(index: FCVIIndex, qn: Array, fqn: Array, cand_idx: Array, k: int):
    """Alg. 1 lines 10-16: combined-score re-ranking of candidates.

    qn: (b, d) normalized queries; fqn: (b, m); cand_idx: (b, k').
    Returns (scores (b,k), ids (b,k)).
    """
    cv = index.vectors_n[cand_idx]               # (b, k', d)
    cf = index.filters_n[cand_idx]               # (b, k', m)
    score = combined_score(cv, cf, qn, fqn, index.config.lam,
                           use_pallas=index.config.use_pallas)
    vals, pos = jax.lax.top_k(score, k)
    return vals, jnp.take_along_axis(cand_idx, pos, axis=-1)


@partial(jax.jit, static_argnames=("k", "k_prime"))
def query(index: FCVIIndex, q: Array, f_q: Array, k: int,
          k_prime: Optional[int] = None):
    """Online query processing (Alg. 1 lines 6-16). Batched.

    q: (b, d); f_q: (b, m). Returns (scores (b,k), ids (b,k)).
    """
    cfg = index.config
    kp = k_prime if k_prime is not None else theory.k_prime(
        k, cfg.lam, cfg.resolved_alpha(), index.size, cfg.c)
    qn, fqn = index.transform.normalize(q, f_q)
    q_t = index.transform.apply_normalized(qn, fqn, use_pallas=cfg.use_pallas)
    _, cand = _backend_search(index, q_t, kp)
    return rescore(index, qn, fqn, cand, k)


@partial(jax.jit, static_argnames=("k", "k_prime"))
def multi_probe_query(index: FCVIIndex, q: Array, filter_probes: Array, k: int,
                      k_prime: Optional[int] = None):
    """Range/disjunctive filters (§4.3): probe r representative filter vectors,
    merge + dedup candidates, re-score all, return top-k.

    q: (b, d); filter_probes: (b, r, m) raw filter representatives.
    """
    cfg = index.config
    b, r, m = filter_probes.shape
    kp = k_prime if k_prime is not None else theory.k_prime(
        k, cfg.lam, cfg.resolved_alpha(), index.size, cfg.c)

    qn = index.transform.vec_norm.apply(q)
    fqn = index.transform.filt_norm.apply(filter_probes)       # (b, r, m)
    q_rep = jnp.broadcast_to(qn[:, None, :], (b, r, qn.shape[-1]))
    q_t = index.transform.apply_normalized(q_rep, fqn,
                                           use_pallas=cfg.use_pallas)  # (b, r, d)
    _, cand = _backend_search(index, q_t.reshape(b * r, -1), kp)
    cand = cand.reshape(b, r * kp)
    # dedup: demote duplicate ids so they cannot crowd the candidate set
    sorted_cand = jnp.sort(cand, axis=-1)
    dup = jnp.concatenate(
        [jnp.zeros((b, 1), bool), sorted_cand[:, 1:] == sorted_cand[:, :-1]], axis=-1)
    cv = index.vectors_n[sorted_cand]
    cf = index.filters_n[sorted_cand]
    # filter sim against the NEAREST probe: lam*cos(v,q) is constant across
    # probes, so score = lam*s_v + (1-lam)*max_r s_f_r. The expensive s_v
    # pass over the (b, r*kp, d) candidate tensor runs ONCE (lam=1 makes the
    # fused kernel return pure cos(v,q)); the per-probe passes only touch the
    # small (b, r*kp, m) filter tensor (cf stands in for both kernel operands,
    # which collapses the combine to cos(cf, probe) for any lam).
    s_v = combined_score(cv, cf, qn, fqn[:, 0], 1.0,
                         use_pallas=cfg.use_pallas)
    s_f = combined_score(cf, cf, fqn[:, 0], fqn[:, 0], 0.0,
                         use_pallas=cfg.use_pallas)
    for j in range(1, r):
        s_f = jnp.maximum(
            s_f, combined_score(cf, cf, fqn[:, j], fqn[:, j], 0.0,
                                use_pallas=cfg.use_pallas))
    score = cfg.lam * s_v + (1.0 - cfg.lam) * s_f
    score = jnp.where(dup, -jnp.inf, score)
    vals, pos = jax.lax.top_k(score, k)
    return vals, jnp.take_along_axis(sorted_cand, pos, axis=-1)


# ---------------------------------------------------------------------------
# Predicate (filtered) search support
# ---------------------------------------------------------------------------

def filters_raw(index: FCVIIndex) -> Array:
    """Raw-space attribute table recovered from the stored normalized filters.

    Predicates evaluate over RAW attribute values (``repro.core.filters``);
    an engine built with an explicit ``attributes=`` table uses that, and
    this inverse is the fallback when only the normalized copy exists.
    """
    return index.transform.filt_norm.inverse(index.filters_n)


def fold_queries(index: FCVIIndex, q: Array, fold_raw) -> Array:
    """Transform raw queries against a predicate's raw fold target.

    ``fold_raw`` is the single representative filter point the planner
    derives per predicate (``CompiledPredicate.fold_target_raw``); all of a
    predicate's candidates are scored in this one transformed frame, so
    every physical plan for the predicate ranks identically.
    """
    return index.transform.fold_query(
        q, jnp.asarray(fold_raw, jnp.float32),
        use_pallas=index.config.use_pallas)


# ---------------------------------------------------------------------------
# Ground truth + recall (evaluation oracles)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k",))
def ground_truth_combined(vectors_n: Array, filters_n: Array, qn: Array,
                          fqn: Array, k: int, lam: float):
    """Exact top-k under the paper's combined score (the recall reference)."""
    s_v = cosine_sim(vectors_n[None, :, :], qn[:, None, :])
    s_f = cosine_sim(filters_n[None, :, :], fqn[:, None, :])
    score = lam * s_v + (1.0 - lam) * s_f
    return jax.lax.top_k(score, k)


def recall_at_k(pred_ids: Array, true_ids: Array) -> Array:
    """|pred ∩ true| / k, averaged over the query batch."""
    hits = (pred_ids[:, :, None] == true_ids[:, None, :]).any(-1)
    return jnp.mean(jnp.mean(hits.astype(jnp.float32), axis=-1))


# ---------------------------------------------------------------------------
# Checkpointable state (build -> checkpoint -> restore -> serve lifecycle)
# ---------------------------------------------------------------------------

def index_state(index: FCVIIndex) -> dict:
    """The checkpointable array state of an index, as a nested dict pytree.

    Contains the fitted transform, the re-rank originals and the backend's
    SOURCE arrays only — derived serving layouts (squared norms, the IVF
    grouped slabs, the PQ build-time LUT terms) are rematerialised by
    ``index_from_state``, so checkpoints stay roughly corpus-sized. Paired
    with ``repro.checkpoint.ckpt``: ``ckpt.save(dir, step, index_state(ix))``
    then ``index_from_state(cfg, ckpt.load(dir)[0])``.
    """
    tfm = index.transform
    t = {"alpha": tfm.alpha,
         "vec_mean": tfm.vec_norm.mean, "vec_std": tfm.vec_norm.std,
         "filt_mean": tfm.filt_norm.mean, "filt_std": tfm.filt_norm.std}
    if tfm.centers is not None:
        t["centers"] = tfm.centers
    if tfm.proj is not None:
        t["proj"] = tfm.proj
    cfg = index.config
    b = index.backend
    if cfg.backend == "flat":
        bstate = {"vectors": b.vectors}
        if b.scales is not None:
            bstate["scales"] = b.scales
    elif cfg.backend == "ivf":
        bstate = {"vectors": b.vectors, "centroids": b.centroids,
                  "lists": b.lists, "list_sizes": b.list_sizes}
        if b.scales is not None:
            bstate["scales"] = b.scales
    else:
        bstate = {"codebooks": b.codebooks, "codes": b.codes,
                  "coarse_centers": b.coarse_centers,
                  "coarse_ids": b.coarse_ids}
    return {"transform": t, "backend": bstate,
            "vectors_n": index.vectors_n, "filters_n": index.filters_n}


def index_from_state(config: FCVIConfig, state: dict) -> FCVIIndex:
    """Rebuild an ``FCVIIndex`` from ``index_state`` output (no re-training:
    the fitted normalizers / k-means state come from the checkpoint; only the
    derived serving layouts are rematerialised)."""
    from repro.core.transform import Normalizer

    t = state["transform"]
    tfm = Transform(
        mode=config.mode,
        alpha=jnp.asarray(t["alpha"], jnp.float32),
        vec_norm=Normalizer(mean=jnp.asarray(t["vec_mean"]),
                            std=jnp.asarray(t["vec_std"])),
        filt_norm=Normalizer(mean=jnp.asarray(t["filt_mean"]),
                             std=jnp.asarray(t["filt_std"])),
        centers=jnp.asarray(t["centers"]) if "centers" in t else None,
        proj=jnp.asarray(t["proj"]) if "proj" in t else None,
    )
    from repro.index import quant

    b = state["backend"]
    if config.backend == "flat":
        vectors = jnp.asarray(b["vectors"])
        scales = jnp.asarray(b["scales"]) if "scales" in b else None
        if scales is not None:
            sq_norms = quant.sq_norms_of(vectors, scales)
        else:
            sq_norms = jnp.sum(vectors.astype(jnp.float32) ** 2, axis=-1)
        backend = flat_mod.FlatIndex(vectors=vectors, sq_norms=sq_norms,
                                     scales=scales)
    elif config.backend == "ivf":
        from repro.index.slab import build_grouped

        vectors = jnp.asarray(b["vectors"])
        lists = jnp.asarray(b["lists"])
        scales = jnp.asarray(b["scales"]) if "scales" in b else None
        if scales is not None:
            sq_norms = quant.sq_norms_of(vectors, scales)
            grouped_scales = ivf_mod._group_scales(scales, lists)
        else:
            sq_norms = jnp.sum(vectors.astype(jnp.float32) ** 2, axis=-1)
            grouped_scales = None
        grouped, grouped_sq, valid = build_grouped(vectors, sq_norms, lists)
        backend = ivf_mod.IVFIndex(
            vectors=vectors, sq_norms=sq_norms,
            centroids=jnp.asarray(b["centroids"]), lists=lists,
            list_sizes=jnp.asarray(b["list_sizes"]),
            grouped=grouped, grouped_sq=grouped_sq, valid=valid,
            scales=scales, grouped_scales=grouped_scales)
    else:
        codebooks = jnp.asarray(b["codebooks"])
        coarse_centers = jnp.asarray(b["coarse_centers"])
        ncoarse = coarse_centers.shape[0]
        m, ksub, dsub = codebooks.shape
        centers_sub = coarse_centers.reshape(ncoarse, m, dsub)
        backend = pq_mod.PQIndex(
            codebooks=codebooks, codes=jnp.asarray(b["codes"]),
            coarse_centers=coarse_centers,
            coarse_ids=jnp.asarray(b["coarse_ids"]),
            cb_sq=jnp.sum(codebooks * codebooks, axis=-1),
            coarse_dot=jnp.einsum("cmd,mkd->cmk", centers_sub, codebooks))
    return FCVIIndex(config=config, transform=tfm, backend=backend,
                     vectors_n=jnp.asarray(state["vectors_n"]),
                     filters_n=jnp.asarray(state["filters_n"]))


# ---------------------------------------------------------------------------
# Updates: delta buffer + compaction (production insert path)
# ---------------------------------------------------------------------------

def extend(index: FCVIIndex, new_vectors: Array, new_filters: Array) -> FCVIIndex:
    """Append new rows and rebuild the backend over the transformed corpus.

    Normalizer/centers are kept frozen (same geometry; matches the paper's
    'incremental filter updates' §4.2 — a full refit is a separate offline
    job). The serving engine batches inserts in a delta FlatIndex and calls
    this on compaction.
    """
    tfm = index.transform
    vn_new = tfm.vec_norm.apply(new_vectors)
    fn_new = tfm.filt_norm.apply(new_filters)
    vectors_n = jnp.concatenate([index.vectors_n, vn_new], axis=0)
    filters_n = jnp.concatenate([index.filters_n, fn_new], axis=0)
    transformed = tfm.apply_normalized(vectors_n, filters_n)
    backend = build_backend(transformed, index.config)
    return FCVIIndex(config=index.config, transform=tfm, backend=backend,
                     vectors_n=vectors_n, filters_n=filters_n)
