"""Closed-form quantities from the paper's theory (§5).

These are used at serving time (k' sizing, Alg. 1 line 7), at index-build time
(alpha* for guaranteed cluster separation, Thm 5.3) and by the property tests
(Thm 5.1 distance identities).
"""
from __future__ import annotations

import jax.numpy as jnp


def transformed_sq_distance(v_a, v_b, f_a, f_b, alpha: float):
    """Closed form of ||psi(v_a,f_a,a) - psi(v_b,f_b,a)||^2 (Thm 5.1 proof).

    = ||va - vb||^2 + (d/m) a^2 ||fa - fb||^2
      - 2 a sum_j <va^(j) - vb^(j), fa - fb>
    """
    d, m = v_a.shape[-1], f_a.shape[-1]
    segs = d // m
    dv = (v_a - v_b).reshape(*v_a.shape[:-1], segs, m)
    df = f_a - f_b
    base = jnp.sum((v_a - v_b) ** 2, axis=-1)
    quad = segs * alpha**2 * jnp.sum(df * df, axis=-1)
    cross = 2.0 * alpha * jnp.sum(dv * df[..., None, :], axis=(-1, -2))
    return base + quad - cross


def alpha_star(d_v: float, delta_f: float, d: int, m: int) -> float:
    """Thm 5.3: minimum alpha guaranteeing complete cluster separation.

    Requires (d/m) * delta_f > 2 * d_v (feasibility); returns +inf otherwise.

    alpha* = sqrt((2 D_v + D_v^2) / ((d/m) delta_f^2 - 2 D_v delta_f))
    """
    segs = d / m
    denom = segs * delta_f**2 - 2.0 * d_v * delta_f
    feasible = segs * delta_f > 2.0 * d_v
    val = jnp.sqrt(jnp.maximum(2.0 * d_v + d_v**2, 0.0) / jnp.maximum(denom, 1e-30))
    return jnp.where(feasible & (denom > 0), val, jnp.inf)


def optimal_alpha(lam: float) -> float:
    """Thm 5.4 optimality note: alpha = sqrt((1-lam)/lam), clipped to >= 1.

    Pure Python (not jnp): called with static config floats inside jitted
    query processing, where the result must stay concrete.
    """
    import math

    lam = min(max(float(lam), 1e-6), 1.0)
    return max(1.0, math.sqrt((1.0 - lam) / lam))


def k_prime(k: int, lam: float, alpha: float, n: int, c: float = 4.0) -> int:
    """Alg. 1 line 7: k' = min(c * k/lam * 1/alpha^2, N).

    Static python ints in, static int out — k' feeds static top-k shapes.
    """
    lam = max(float(lam), 1e-6)
    alpha = max(float(alpha), 1.0)
    kp = int(c * (k / lam) * (1.0 / alpha**2))
    return max(k, min(max(kp, k), n))


def separation_margin(d_v: float, delta_f: float, d: int, m: int, alpha: float):
    """Worst-case inter-cluster distance minus intra-cluster diameter.

    From Thm 5.3's proof: inter^2 >= (d/m) a^2 delta_f^2 - 2 a D_v delta_f,
    intra <= D_v. Positive margin => complete separation.
    """
    segs = d / m
    inter_sq = jnp.maximum(segs * alpha**2 * delta_f**2 - 2.0 * alpha * d_v * delta_f, 0.0)
    return jnp.sqrt(inter_sq) - d_v


def cluster_stats(filters, labels=None):
    """Compute (D_v-style) delta_f = min inter-label filter distance.

    Utility for tests/benchmarks; O(n^2), intended for small n.
    """
    import jax.numpy as jnp  # local: keep module import-light

    f = filters
    d2 = (
        jnp.sum(f * f, -1)[:, None]
        - 2.0 * f @ f.T
        + jnp.sum(f * f, -1)[None, :]
    )
    d2 = jnp.maximum(d2, 0.0)
    if labels is None:
        labels = jnp.arange(f.shape[0])
    diff = labels[:, None] != labels[None, :]
    big = jnp.where(diff, d2, jnp.inf)
    return jnp.sqrt(jnp.min(big))
