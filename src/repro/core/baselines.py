"""Baseline filtered-search strategies the paper compares against (§2.2, §6.1.2).

* post-filter : ANN search on raw vectors, then drop candidates failing the
                predicate (recall collapses under selective filters).
* pre-filter  : evaluate the predicate over the corpus, exact search inside
                the eligible subset (slow when the subset is large).
* hybrid      : UNIFY-style — segment the corpus by the primary filter key and
                pick pre- vs post- per query from the predicate's range size.

Predicates are axis-aligned boxes over raw filter values (range predicates;
categorical equality is a zero-width box on the one-hot dim).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.index import flat as flat_mod

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BoxPredicate:
    """match iff low_j <= f_j <= high_j for all constrained dims j.

    Unconstrained dims use low=-inf / high=+inf.
    """

    low: Array   # (m,)
    high: Array  # (m,)

    def tree_flatten(self):
        return (self.low, self.high), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def mask(self, filters: Array) -> Array:
        return jnp.all((filters >= self.low) & (filters <= self.high), axis=-1)

    def center(self) -> Array:
        lo = jnp.where(jnp.isfinite(self.low), self.low, 0.0)
        hi = jnp.where(jnp.isfinite(self.high), self.high, 0.0)
        return 0.5 * (lo + hi)

    def to_filter_query(self, filters: Array) -> Array:
        """Soft-predicate encoding (§4.3): constrained dims take the range
        center; unconstrained dims take the corpus mean (the neutral value
        under per-dim standardization)."""
        constrained = jnp.isfinite(self.low) | jnp.isfinite(self.high)
        mean = jnp.mean(filters, axis=0)
        return jnp.where(constrained, self.center(), mean)

    def probes(self, r: int) -> Array:
        """r representative filter vectors spanning the box (multi-probe §4.3)."""
        lo = jnp.where(jnp.isfinite(self.low), self.low, 0.0)
        hi = jnp.where(jnp.isfinite(self.high), self.high, 0.0)
        t = jnp.linspace(0.0, 1.0, r)[:, None]
        return lo[None, :] * (1 - t) + hi[None, :] * t


# ---------------------------------------------------------------------------
# Post-filtering
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "oversample"))
def post_filter_search(index: flat_mod.FlatIndex, filters: Array, queries: Array,
                       pred: BoxPredicate, k: int, oversample: int = 10):
    """ANN (here exact-flat) on raw vectors, then predicate mask, then top-k."""
    kp = min(k * oversample, index.size)
    vals, idx = flat_mod.search(index, queries, kp)
    ok = pred.mask(filters[idx])               # (q, kp)
    vals = jnp.where(ok, vals, -jnp.inf)
    top_vals, pos = jax.lax.top_k(vals, k)
    return top_vals, jnp.take_along_axis(idx, pos, axis=-1)


# ---------------------------------------------------------------------------
# Pre-filtering
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k",))
def pre_filter_search(index: flat_mod.FlatIndex, filters: Array, queries: Array,
                      pred: BoxPredicate, k: int):
    """Predicate over the whole corpus first, exact search on survivors."""
    mask = pred.mask(filters)
    return flat_mod.search_masked(index, queries, k, mask)


# ---------------------------------------------------------------------------
# Hybrid (UNIFY-style segmented index with range-aware strategy selection)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HybridIndex:
    """Corpus sorted by a primary filter key + segment boundaries.

    Mimics UNIFY's segmented inclusive graph: S contiguous segments sorted on
    the primary key support range pre-filtering by slicing segments; wide
    ranges fall back to post-filtering on the global index.
    """

    flat: flat_mod.FlatIndex    # rows sorted by primary key
    filters: Array              # (n, m) in sorted order
    perm: Array                 # sorted row -> original id
    key_dim: int
    seg_starts: Array           # (S,) first row of each segment
    seg_key_min: Array          # (S,)
    seg_key_max: Array          # (S,)


def build_hybrid(vectors: Array, filters: Array, key_dim: int = 0,
                 n_segments: int = 32) -> HybridIndex:
    keys = np.asarray(filters[:, key_dim])
    perm = np.argsort(keys, kind="stable")
    v_sorted = jnp.asarray(np.asarray(vectors)[perm])
    f_sorted = jnp.asarray(np.asarray(filters)[perm])
    n = len(perm)
    bounds = np.linspace(0, n, n_segments + 1).astype(np.int64)
    starts = bounds[:-1]
    kmin = np.asarray([keys[perm[s]] for s in starts])
    kmax = np.asarray([keys[perm[e - 1]] for e in bounds[1:]])
    return HybridIndex(
        flat=flat_mod.build(v_sorted),
        filters=f_sorted,
        perm=jnp.asarray(perm),
        key_dim=key_dim,
        seg_starts=jnp.asarray(starts),
        seg_key_min=jnp.asarray(kmin),
        seg_key_max=jnp.asarray(kmax),
    )


def hybrid_search(index: HybridIndex, queries: Array, pred: BoxPredicate, k: int,
                  pre_threshold: float = 0.25, oversample: int = 10):
    """Range-aware strategy selection (host-level, per query batch).

    Estimates predicate selectivity from the segment key ranges; below
    ``pre_threshold`` uses segment-sliced pre-filtering, else post-filtering.
    Returns ids in ORIGINAL corpus numbering.
    """
    lo = float(np.asarray(pred.low)[index.key_dim])
    hi = float(np.asarray(pred.high)[index.key_dim])
    kmin = np.asarray(index.seg_key_min)
    kmax = np.asarray(index.seg_key_max)
    overlap = (kmax >= lo) & (kmin <= hi)
    frac = overlap.sum() / max(len(overlap), 1)

    if frac <= pre_threshold:
        seg_mask = jnp.asarray(overlap)
        row_seg = jnp.searchsorted(index.seg_starts,
                                   jnp.arange(index.flat.size), side="right") - 1
        row_ok = seg_mask[row_seg] & pred.mask(index.filters)
        vals, idx = flat_mod.search_masked(index.flat, queries, k, row_ok)
    else:
        vals, idx = post_filter_search(index.flat, index.filters, queries, pred,
                                       k, oversample)
    return vals, index.perm[idx]


# ---------------------------------------------------------------------------
# Binary-predicate recall oracle (baseline ground truth)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k",))
def ground_truth_filtered(vectors: Array, filters: Array, queries: Array,
                          pred: BoxPredicate, k: int):
    """Exact top-k among predicate-satisfying rows (for baseline recall)."""
    q2 = jnp.sum(queries * queries, axis=-1, keepdims=True)
    sq = jnp.sum(vectors * vectors, axis=-1)
    scores = -(q2 - 2.0 * queries @ vectors.T + sq[None, :])
    scores = jnp.where(pred.mask(filters)[None, :], scores, -jnp.inf)
    return jax.lax.top_k(scores, k)
