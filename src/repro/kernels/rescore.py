"""Pallas kernel: fused combined-score re-ranking (Alg. 1 line 13).

score = lam * cos(v_i, q) + (1 - lam) * cos(f_i, F_q)

Both cosine similarities, their norms and the affine combine are fused into
one VMEM pass over the gathered candidate tile, so re-scoring costs one read
of the (kp x d) candidate block instead of four separate elementwise passes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEF_BLOCK_B = 8


def _kernel(cv_ref, cf_ref, q_ref, fq_ref, lam_ref, out_ref):
    # loads cast up front: bf16 / int8-dequantized candidate tiles are
    # accepted and the norms + dots accumulate in fp32 (no-op for fp32)
    cv = cv_ref[...].astype(jnp.float32)   # (bb, kp, d)
    cf = cf_ref[...].astype(jnp.float32)   # (bb, kp, m)
    q = q_ref[...].astype(jnp.float32)     # (bb, d)
    fq = fq_ref[...].astype(jnp.float32)   # (bb, m)
    lam = lam_ref[0]

    def cos(a, b):  # a: (bb, kp, x), b: (bb, x)
        # mul+sum, not a dot_general contraction: each row reduces
        # independently, so a candidate's score does not depend on its
        # k-position or the tile width (a contraction's CPU lowering treats
        # main-loop vs remainder k-rows differently, and the routed path
        # re-scores the same candidate at a different k' than the dense
        # path).  Callers feed gather-produced tiles, so in interpret mode
        # the inlined reduction cannot fuse into a path-dependent producer.
        num = jnp.sum(a * b[:, None, :], axis=-1)
        na = jnp.sqrt(jnp.sum(a * a, axis=-1))
        nb = jnp.sqrt(jnp.sum(b * b, axis=-1))
        return num / (na * nb[:, None] + 1e-8)

    out_ref[...] = (lam * cos(cv, q) + (1.0 - lam) * cos(cf, fq)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def rescore(cand_v, cand_f, qn, fqn, lam, *, block_b: int = DEF_BLOCK_B,
            interpret: bool = True):
    """cand_v: (b, kp, d); cand_f: (b, kp, m); qn: (b, d); fqn: (b, m)."""
    b, kp, d = cand_v.shape
    m = cand_f.shape[-1]
    block_b = min(block_b, b)
    if b % block_b:
        raise ValueError(f"b={b} must be divisible by block_b={block_b}")
    lam_arr = jnp.asarray(lam, jnp.float32).reshape(1)
    grid = (b // block_b,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, kp, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, kp, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, kp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kp), jnp.float32),
        interpret=interpret,
    )(cand_v, cand_f, qn, fqn, lam_arr)
