"""Pallas kernel: PQ ADC scoring — LUT gather-accumulate as one-hot matmuls.

scores[n] = sum_m lut[m, codes[n, m]]

GPU PQ kernels use per-lane shared-memory gathers; the TPU adaptation turns
each subspace's gather into a (rows x ksub) one-hot times (ksub,) LUT-column
product, which the MXU executes at full rate and which needs no dynamic
addressing. codes stream through VMEM in row blocks; the LUT stays resident
(M x ksub floats, a few KB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEF_BLOCK_ROWS = 512


def _kernel(codes_ref, lut_ref, out_ref, *, ksub: int):
    codes = codes_ref[...]            # (bn, M) int32
    lut = lut_ref[...]                # (M, ksub)
    bn, m = codes.shape
    total = jnp.zeros((bn,), jnp.float32)
    for j in range(m):                # M is small + static: unrolled
        onehot = (codes[:, j][:, None]
                  == jax.lax.broadcasted_iota(jnp.int32, (bn, ksub), 1))
        total = total + jnp.dot(onehot.astype(jnp.float32), lut[j],
                                preferred_element_type=jnp.float32)
    out_ref[...] = total.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def pq_score(codes, lut, *, block_rows: int = DEF_BLOCK_ROWS,
             interpret: bool = True):
    """codes: (n, M) int32; lut: (M, ksub) f32. Returns squared dists (n,)."""
    n, m = codes.shape
    ksub = lut.shape[-1]
    block_rows = min(block_rows, n)
    if n % block_rows:
        raise ValueError(f"n={n} must divide by block_rows={block_rows}")
    kernel = functools.partial(_kernel, ksub=ksub)
    return pl.pallas_call(
        kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, m), lambda i: (i, 0)),
            pl.BlockSpec((m, ksub), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(codes, lut)


def _qdot_kernel(q_ref, cb_ref, out_ref):
    q = q_ref[...][:, 0, :]            # (bq, dsub)
    cb = cb_ref[...][0]                # (ksub, dsub)
    out = jnp.dot(q, cb.T, preferred_element_type=jnp.float32)
    out_ref[...] = out[:, None, :].astype(out_ref.dtype)


DEF_QDOT_BLOCK_Q = 128


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def pq_lut_qdot(queries_sub, codebooks, *, block_q: int = DEF_QDOT_BLOCK_Q,
                interpret: bool = True):
    """The q . codebook cross term of PQ LUT construction as one fused matmul.

    queries_sub: (q, M, dsub) queries split into subspaces; codebooks:
    (M, ksub, dsub). Returns (q, M, ksub) with out[i, m, j] =
    <queries_sub[i, m], codebooks[m, j]> — the dominant term of
    ``repro.index.pq.compute_luts`` (the residual-norm and build-time terms
    stay jnp). Grid is (query-block, subspace): each subspace's codebook
    stays VMEM-resident while query blocks stream through the MXU. Queries
    are zero-padded to the block multiple and sliced back off.
    """
    q, m, dsub = queries_sub.shape
    ksub = codebooks.shape[1]
    block_q = min(block_q, q)
    pad = -q % block_q
    if pad:
        queries_sub = jnp.concatenate(
            [queries_sub, jnp.zeros((pad, m, dsub), queries_sub.dtype)],
            axis=0)
    out = pl.pallas_call(
        _qdot_kernel,
        grid=((q + pad) // block_q, m),
        in_specs=[
            pl.BlockSpec((block_q, 1, dsub), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, ksub, dsub), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, 1, ksub), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((q + pad, m, ksub), jnp.float32),
        interpret=interpret,
    )(queries_sub, codebooks)
    return out[:q]


def _batch_kernel(codes_ref, lut_ref, out_ref, *, ksub: int):
    codes = codes_ref[...]            # (bn, M) int32
    lut = lut_ref[...][0]             # (M, ksub)
    bn, m = codes.shape
    total = jnp.zeros((bn,), jnp.float32)
    for j in range(m):                # M is small + static: unrolled
        onehot = (codes[:, j][:, None]
                  == jax.lax.broadcasted_iota(jnp.int32, (bn, ksub), 1))
        total = total + jnp.dot(onehot.astype(jnp.float32), lut[j],
                                preferred_element_type=jnp.float32)
    out_ref[...] = total[None, :].astype(out_ref.dtype)


DEF_BATCH_BLOCK_ROWS = 256


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def pq_score_batch(codes, luts, *, block_rows: int = DEF_BATCH_BLOCK_ROWS,
                   interpret: bool = True):
    """Multi-query ADC: codes (n, M) int32, luts (q, M, ksub) -> scores (q, n).

    Grid is (query, row-block): each query's LUT stays resident while code
    blocks stream through VMEM. Rows are zero-padded to a block multiple and
    the pad columns sliced off the result.
    """
    n, m = codes.shape
    q, _, ksub = luts.shape
    block_rows = min(block_rows, n)
    pad = -n % block_rows
    if pad:
        codes = jnp.concatenate(
            [codes, jnp.zeros((pad, m), codes.dtype)], axis=0)
    n_pad = n + pad
    kernel = functools.partial(_batch_kernel, ksub=ksub)
    out = pl.pallas_call(
        kernel,
        grid=(q, n_pad // block_rows),
        in_specs=[
            pl.BlockSpec((block_rows, m), lambda i, j: (j, 0)),
            pl.BlockSpec((1, m, ksub), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_rows), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, n_pad), jnp.float32),
        interpret=interpret,
    )(codes, luts)
    return out[:, :n]
