"""Pallas TPU kernels for FCVI's serving hot spots (+ jnp oracles in ref.py)."""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
