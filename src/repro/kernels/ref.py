"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``ref_*`` function is the semantic ground truth; kernel tests sweep
shapes/dtypes and assert allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def partition_matrix(d: int, m: int, dtype=jnp.float32) -> Array:
    """P in R^{m x d} with P[i, j] = 1 iff j % m == i.

    psi_partition(v, f, a) == v - a * (f @ P): subtracting f from every
    m-segment equals one (m x d) matmul — this turns all three psi variants
    into the same fused kernel (partition: P; embedding: W^T; cluster: P
    applied to substituted centers).
    """
    cols = jnp.arange(d) % m
    return (cols[None, :] == jnp.arange(m)[:, None]).astype(dtype)


def ref_fused_transform(v: Array, f: Array, proj: Array, alpha,
                        mean_v: Array, std_v: Array,
                        mean_f: Array, std_f: Array) -> Array:
    """Fused normalize + psi: ((v-mu)/sd) - alpha * ((f-mu_f)/sd_f) @ proj."""
    vn = (v - mean_v) / std_v
    fn = (f - mean_f) / std_f
    return vn - alpha * (fn @ proj)


def ref_score_topk(corpus: Array, sq_norms: Array, queries: Array, k: int,
                   scales=None, mask=None):
    """Exact negative-squared-L2 top-k: the serving inner loop.

    ``scales`` (n,) is the int8 storage rung's per-row dequant scale; like
    the kernel it multiplies the matmul OUTPUT column (fp32 accumulation).
    ``mask`` (n,) float 0/1 is the filter algebra's candidate mask: rows at
    0 score -inf (their ids collapse to 0 like every other dead slot).
    """
    q2 = jnp.sum(queries * queries, axis=-1, keepdims=True)
    dot = queries @ corpus.astype(queries.dtype).T
    if scales is not None:
        dot = dot * scales[None, :]
    scores = -(q2 - 2.0 * dot + sq_norms[None, :])
    if mask is not None:
        scores = jnp.where(mask[None, :] > 0.5, scores, -jnp.inf)
    vals, ids = jax.lax.top_k(scores, k)
    if mask is not None:
        ids = jnp.where(jnp.isneginf(vals), 0, ids)
    return vals, ids


def ref_score_topk_rows(corpus: Array, sq_norms: Array, payload_v: Array,
                        payload_f: Array, queries: Array, k: int,
                        scales=None):
    """Oracle for the rows-returning flat kernel: top-k ids plus the
    winners' dequantized scan rows and payload rows (gathered by id — the
    semantic definition of what the kernel carries through VMEM)."""
    vals, ids = ref_score_topk(corpus, sq_norms, queries, k, scales=scales)
    scan_rows = corpus[ids].astype(jnp.float32)
    if scales is not None:
        scan_rows = scan_rows * scales[ids][..., None]
    return (vals, ids, scan_rows,
            payload_v[ids].astype(jnp.float32),
            payload_f[ids].astype(jnp.float32))


def ref_rescore(cand_v: Array, cand_f: Array, qn: Array, fqn: Array, lam):
    """Combined cosine score per candidate (Alg. 1 line 13).

    cand_v: (b, kp, d); cand_f: (b, kp, m); qn: (b, d); fqn: (b, m).
    """
    def cos(a, b):
        num = jnp.sum(a * b, axis=-1)
        den = (jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(b, axis=-1) + 1e-8)
        return num / den

    s_v = cos(cand_v, qn[:, None, :])
    s_f = cos(cand_f, fqn[:, None, :])
    return lam * s_v + (1.0 - lam) * s_f


def ref_ivf_score_topk(grouped: Array, grouped_sq: Array, valid: Array,
                       probes: Array, query: Array, k: int):
    """IVF probed-slab scoring for ONE query.

    grouped: (nlist, max_list, d) corpus grouped by list; valid: (nlist,
    max_list) bool; probes: (nprobe,) list ids. Returns (vals, flat_ids)
    where flat_ids index into grouped.reshape(-1, d).
    """
    slabs = grouped[probes]            # (nprobe, max_list, d)
    sq = grouped_sq[probes]
    ok = valid[probes]
    q2 = jnp.sum(query * query)
    s = -(q2 - 2.0 * slabs @ query + sq)
    s = jnp.where(ok, s, -jnp.inf)
    max_list = grouped.shape[1]
    flat_ids = probes[:, None] * max_list + jnp.arange(max_list)[None, :]
    s = s.reshape(-1)
    vals, pos = jax.lax.top_k(s, k)
    return vals, flat_ids.reshape(-1)[pos]


def ref_ivf_score_topk_batch(grouped: Array, grouped_sq: Array, valid: Array,
                             probes: Array, queries: Array, k: int,
                             scales=None):
    """Batched IVF probed-slab scoring in the KERNEL's score convention.

    probes: (b, nprobe); queries: (b, d). Returns (vals (b, k), flat_ids
    (b, k)) with scores 2<x,q> - ||x||^2 (the ||q||^2 constant dropped, like
    the Pallas kernel) and flat ids into grouped.reshape(-1, d).
    ``scales`` (nlist, max_list): int8 per-row dequant of the dot output.
    """
    max_list = grouped.shape[1]

    def one(probe, query):
        slabs = grouped[probe]                     # (nprobe, max_list, d)
        sq = grouped_sq[probe]
        ok = valid[probe]
        s = 2.0 * (slabs.astype(query.dtype) @ query)
        if scales is not None:
            s = s * scales[probe]
        s = s - sq
        s = jnp.where(ok, s, -jnp.inf)
        flat_ids = probe[:, None] * max_list + jnp.arange(max_list)[None, :]
        vals, pos = jax.lax.top_k(s.reshape(-1), k)
        ids = flat_ids.reshape(-1)[pos]
        return vals, jnp.where(jnp.isneginf(vals), 0, ids)

    return jax.vmap(one)(probes, queries)


def _dedup_scores(grouped, grouped_sq, valid, uniq, member, queries,
                  scales=None, mask=None):
    """Shared (b, s*max_list) masked score matrix + flat id map for the
    dedup oracles (kernel score convention)."""
    max_list = grouped.shape[1]
    slabs = grouped[uniq]                              # (s, max_list, d)
    sq = grouped_sq[uniq]
    ok = valid[uniq]
    if mask is not None:
        ok = ok & (mask[uniq] > 0.5)
    s = 2.0 * jnp.einsum("bd,smd->bsm", queries,
                         slabs.astype(queries.dtype))
    if scales is not None:
        s = s * scales[uniq][None]
    s = s - sq[None]
    keep = ok[None, :, :] & member.T[:, :, None]       # (b, s, max_list)
    s = jnp.where(keep, s, -jnp.inf)
    flat_ids = (uniq[:, None] * max_list
                + jnp.arange(max_list)[None, :]).reshape(-1)
    return s.reshape(s.shape[0], -1), flat_ids


def ref_ivf_score_topk_dedup(grouped: Array, grouped_sq: Array, valid: Array,
                             uniq: Array, member: Array, queries: Array,
                             k: int, scales=None, mask=None):
    """Probe-major deduplicated slab scoring (the dedup kernel's oracle).

    uniq: (s,) unique probed list ids; member: (s, b) bool — query b probed
    list uniq[s]. Same score/id convention as ``ref_ivf_score_topk_batch``.
    ``mask`` (nlist, max_list) float 0/1 is the filter algebra's candidate
    mask, ANDed into ``valid`` slot-wise.
    """
    s, flat_ids = _dedup_scores(grouped, grouped_sq, valid, uniq, member,
                                queries, scales=scales, mask=mask)
    vals, pos = jax.lax.top_k(s, k)
    ids = flat_ids[pos]
    return vals, jnp.where(jnp.isneginf(vals), 0, ids)


def ref_ivf_score_topk_dedup_rows(grouped: Array, grouped_sq: Array,
                                  valid: Array, uniq: Array, member: Array,
                                  queries: Array, payload_v: Array,
                                  payload_f: Array, k: int, scales=None):
    """Oracle for the rows-returning dedup kernel: payload rows gathered by
    the winning flat ids; unfilled (-inf) slots carry ZERO rows, matching
    the kernel's init state for never-written output slots."""
    s, flat_ids = _dedup_scores(grouped, grouped_sq, valid, uniq, member,
                                queries, scales=scales)
    vals, pos = jax.lax.top_k(s, k)
    ids = flat_ids[pos]
    dv = payload_v.shape[-1]
    m = payload_f.shape[-1]
    rows_v = payload_v.reshape(-1, dv)[ids].astype(jnp.float32)
    rows_f = payload_f.reshape(-1, m)[ids].astype(jnp.float32)
    dead = jnp.isneginf(vals)
    rows_v = jnp.where(dead[..., None], 0.0, rows_v)
    rows_f = jnp.where(dead[..., None], 0.0, rows_f)
    return (vals, jnp.where(dead, 0, ids), rows_v, rows_f)


def ref_pq_lut_qdot(queries_sub: Array, codebooks: Array) -> Array:
    """PQ LUT q.codebook cross term: (q, M, dsub) x (M, ksub, dsub) ->
    (q, M, ksub), out[i, m, j] = <queries_sub[i, m], codebooks[m, j]>."""
    return jnp.einsum("qmd,mkd->qmk", queries_sub, codebooks)


def ref_pq_score_batch(codes: Array, luts: Array) -> Array:
    """Multi-query ADC: codes (n, M), luts (q, M, ksub) -> scores (q, n)."""
    return jax.vmap(lambda lut: ref_pq_score(codes, lut))(luts)


def ref_pq_score(codes: Array, lut: Array) -> Array:
    """ADC: scores (n,) = sum_m lut[m, codes[n, m]] (squared distances)."""
    n, m = codes.shape
    per = jnp.take_along_axis(lut.T[None, :, :], codes[:, None, :], axis=1)[:, 0, :]
    return jnp.sum(per, axis=-1)
