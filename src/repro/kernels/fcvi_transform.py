"""Pallas kernel: fused per-dim normalize + psi transform.

out = (v - mu_v)/sd_v - alpha * ((f - mu_f)/sd_f) @ P

One pass over the corpus: rows stream through VMEM in (block_rows x d) tiles;
the (m x d) projection P (partition tiling matrix, or learned W^T) and the
normalizer vectors stay resident. The matmul form keeps the filter fold on
the MXU instead of a lane-misaligned reshape (m is typically 2-8, far below
the 128-lane tile, so the reshape formulation would waste the vector unit).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEF_BLOCK_ROWS = 256


def _kernel(v_ref, f_ref, proj_ref, alpha_ref, mv_ref, sv_ref, mf_ref, sf_ref,
            out_ref):
    v = v_ref[...]
    f = f_ref[...]
    alpha = alpha_ref[0]
    vn = (v - mv_ref[...][None, :]) / sv_ref[...][None, :]
    fn = (f - mf_ref[...][None, :]) / sf_ref[...][None, :]
    fold = jnp.dot(fn, proj_ref[...], preferred_element_type=jnp.float32)
    out_ref[...] = (vn - alpha * fold).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_transform(v, f, proj, alpha, mean_v, std_v, mean_f, std_f,
                    *, block_rows: int = DEF_BLOCK_ROWS, interpret: bool = True):
    """v: (n, d); f: (n, m); proj: (m, d). Returns transformed (n, d)."""
    n, d = v.shape
    m = f.shape[-1]
    block_rows = min(block_rows, n)
    if n % block_rows:
        raise ValueError(f"n={n} must be divisible by block_rows={block_rows}")
    grid = (n // block_rows,)
    alpha_arr = jnp.asarray(alpha, jnp.float32).reshape(1)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, m), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), v.dtype),
        interpret=interpret,
    )(v, f, proj, alpha_arr, mean_v, std_v, mean_f, std_f)
