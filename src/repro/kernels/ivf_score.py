"""Pallas kernel: IVF probed-slab scoring with scalar-prefetched list ids.

The IVF corpus is stored grouped-by-list as a dense (nlist, max_list, d)
slab array (built once at ``IVFIndex.build`` time). The probe ids selected by
the coarse quantizer are passed as a scalar-prefetch operand so the BlockSpec
index_map can route each grid step's DMA directly to the probed slab — the
TPU idiom for data-dependent gathers (the block-table indirection pattern),
replacing the GPU's per-row gather.

The batched variant runs a (batch, nprobe) grid: the probe dimension is the
inner (sequential) axis, so each query's running top-k accumulates across its
probes while the output block revisits the same (1, k) row. Only
nprobe/nlist of the corpus is ever read per query.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fused_score_topk import _select_topk, NEG_INF


def _batch_kernel(probes_ref, slab_ref, sq_ref, valid_ref, q_ref, vals_ref,
                  idx_ref, *, k: int, max_list: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    slab = slab_ref[...][0]            # (max_list, d)
    sq = sq_ref[...][0]                # (max_list,)
    ok = valid_ref[...][0]             # (max_list,) float 0/1
    q = q_ref[...][0]                  # (d,)

    s = 2.0 * jnp.dot(slab, q, preferred_element_type=jnp.float32) - sq
    s = jnp.where(ok > 0.5, s, NEG_INF)[None, :]        # (1, max_list)
    list_id = probes_ref[i, j]
    gids = (list_id * max_list
            + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1))

    cat_v = jnp.concatenate([vals_ref[...], s], axis=-1)
    cat_i = jnp.concatenate([idx_ref[...], gids], axis=-1)
    new_v, new_i = _select_topk(cat_v, cat_i, k)
    vals_ref[...] = new_v.astype(vals_ref.dtype)
    idx_ref[...] = new_i


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def ivf_score_topk_batch(grouped, grouped_sq, valid, probes, queries, k: int,
                         *, interpret: bool = True):
    """Multi-query probed search over the grouped slab layout.

    grouped: (nlist, max_list, d); grouped_sq: (nlist, max_list);
    valid: (nlist, max_list) float 0/1; probes: (b, nprobe) int32;
    queries: (b, d). Returns (vals (b, k), flat_ids (b, k)) with flat ids
    into grouped.reshape(-1, d). Scores are 2<x,q> - ||x||^2 (monotone in
    negative squared distance — the ||q||^2 constant is dropped).
    """
    nlist, max_list, d = grouped.shape
    b, nprobe = probes.shape
    kernel = functools.partial(_batch_kernel, k=k, max_list=max_list)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, nprobe),
        in_specs=[
            pl.BlockSpec((1, max_list, d), lambda i, j, probes: (probes[i, j], 0, 0)),
            pl.BlockSpec((1, max_list), lambda i, j, probes: (probes[i, j], 0)),
            pl.BlockSpec((1, max_list), lambda i, j, probes: (probes[i, j], 0)),
            pl.BlockSpec((1, d), lambda i, j, probes: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, k), lambda i, j, probes: (i, 0)),
            pl.BlockSpec((1, k), lambda i, j, probes: (i, 0)),
        ),
    )
    vals, idx = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ),
        interpret=interpret,
    )(probes, grouped, grouped_sq, valid, queries)
    return vals, idx


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def ivf_score_topk(grouped, grouped_sq, valid, probes, query, k: int, *,
                   interpret: bool = True):
    """Single-query probed search (batch size 1 of the batched kernel).

    probes: (nprobe,) int32; query: (d,). Returns (vals (k,), flat_ids (k,)).
    """
    vals, idx = ivf_score_topk_batch(
        grouped, grouped_sq, valid, probes[None, :], query[None, :], k,
        interpret=interpret)
    return vals[0], idx[0]
