"""Pallas kernel: IVF probed-slab scoring with scalar-prefetched list ids.

The IVF corpus is stored grouped-by-list as a dense (nlist, max_list, d)
slab array (built once at ``IVFIndex.build`` time). The probe ids selected by
the coarse quantizer are passed as a scalar-prefetch operand so the BlockSpec
index_map can route each grid step's DMA directly to the probed slab — the
TPU idiom for data-dependent gathers (the block-table indirection pattern),
replacing the GPU's per-row gather.

The batched variant runs a (batch, nprobe) grid: the probe dimension is the
inner (sequential) axis, so each query's running top-k accumulates across its
probes while the output block revisits the same (1, k) row. Only
nprobe/nlist of the corpus is ever read per query.

The dedup variant inverts the loop to probe-major: the grid walks the UNIQUE
lists probed by any query in the batch, scoring the whole query batch against
each slab with one MXU matmul and masking queries that did not probe it. A
list shared by many queries is DMA'd from HBM exactly once per batch instead
of once per (query, probe) pair — with batch 64 x nprobe 8 over nlist 64 the
slab traffic drops up to 8x, which is the win that matters on the
bandwidth-bound serving path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fused_score_topk import _select_topk, NEG_INF


def _batch_kernel(probes_ref, slab_ref, sq_ref, valid_ref, q_ref, vals_ref,
                  idx_ref, *, k: int, max_list: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    slab = slab_ref[...][0]            # (max_list, d)
    sq = sq_ref[...][0]                # (max_list,)
    ok = valid_ref[...][0]             # (max_list,) float 0/1
    q = q_ref[...][0]                  # (d,)

    s = 2.0 * jnp.dot(slab, q, preferred_element_type=jnp.float32) - sq
    s = jnp.where(ok > 0.5, s, NEG_INF)[None, :]        # (1, max_list)
    list_id = probes_ref[i, j]
    gids = (list_id * max_list
            + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1))

    cat_v = jnp.concatenate([vals_ref[...], s], axis=-1)
    cat_i = jnp.concatenate([idx_ref[...], gids], axis=-1)
    new_v, new_i = _select_topk(cat_v, cat_i, k)
    vals_ref[...] = new_v.astype(vals_ref.dtype)
    idx_ref[...] = new_i


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def ivf_score_topk_batch(grouped, grouped_sq, valid, probes, queries, k: int,
                         *, interpret: bool = True):
    """Multi-query probed search over the grouped slab layout.

    grouped: (nlist, max_list, d); grouped_sq: (nlist, max_list);
    valid: (nlist, max_list) float 0/1; probes: (b, nprobe) int32;
    queries: (b, d). Returns (vals (b, k), flat_ids (b, k)) with flat ids
    into grouped.reshape(-1, d). Scores are 2<x,q> - ||x||^2 (monotone in
    negative squared distance — the ||q||^2 constant is dropped).
    """
    nlist, max_list, d = grouped.shape
    b, nprobe = probes.shape
    kernel = functools.partial(_batch_kernel, k=k, max_list=max_list)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, nprobe),
        in_specs=[
            pl.BlockSpec((1, max_list, d), lambda i, j, probes: (probes[i, j], 0, 0)),
            pl.BlockSpec((1, max_list), lambda i, j, probes: (probes[i, j], 0)),
            pl.BlockSpec((1, max_list), lambda i, j, probes: (probes[i, j], 0)),
            pl.BlockSpec((1, d), lambda i, j, probes: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, k), lambda i, j, probes: (i, 0)),
            pl.BlockSpec((1, k), lambda i, j, probes: (i, 0)),
        ),
    )
    vals, idx = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ),
        interpret=interpret,
    )(probes, grouped, grouped_sq, valid, queries)
    return vals, idx


def _dedup_kernel(uniq_ref, slab_ref, sq_ref, valid_ref, member_ref, q_ref,
                  vals_ref, idx_ref, *, k: int, max_list: int):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    slab = slab_ref[...][0]            # (max_list, d)
    sq = sq_ref[...][0]                # (max_list,)
    ok = valid_ref[...][0]             # (max_list,) float 0/1
    mem = member_ref[...][0]           # (b,) float 0/1: query probed this list
    q = q_ref[...]                     # (b, d)

    scores = 2.0 * jnp.dot(q, slab.T, preferred_element_type=jnp.float32)
    scores = scores - sq[None, :]                       # (b, max_list)
    keep = (ok > 0.5)[None, :] & (mem > 0.5)[:, None]
    scores = jnp.where(keep, scores, NEG_INF)
    list_id = uniq_ref[s]
    gids = (list_id * max_list
            + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1))

    cat_v = jnp.concatenate([vals_ref[...], scores], axis=-1)
    cat_i = jnp.concatenate([idx_ref[...], gids], axis=-1)
    new_v, new_i = _select_topk(cat_v, cat_i, k)
    vals_ref[...] = new_v.astype(vals_ref.dtype)
    idx_ref[...] = new_i


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def ivf_score_topk_dedup(grouped, grouped_sq, valid, uniq, member, queries,
                         k: int, *, interpret: bool = True):
    """Probe-major batched slab search over the deduplicated probed lists.

    grouped: (nlist, max_list, d); grouped_sq/valid: (nlist, max_list);
    uniq: (s,) int32 unique probed list ids (tail slots may repeat a filler
    id — they must have an all-zero ``member`` column); member: (s, b) float
    0/1, 1 iff query b probed list uniq[s]; queries: (b, d).

    Returns (vals (b, k), flat_ids (b, k)) in the same convention as
    ``ivf_score_topk_batch``: scores 2<x,q> - ||x||^2, flat ids into
    grouped.reshape(-1, d). Each unique slab is DMA'd once for the whole
    batch (grid is sequential over slots, queries stay VMEM-resident).
    """
    nlist, max_list, d = grouped.shape
    b = queries.shape[0]
    slots = uniq.shape[0]
    kernel = functools.partial(_dedup_kernel, k=k, max_list=max_list)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(slots,),
        in_specs=[
            pl.BlockSpec((1, max_list, d), lambda s, uniq: (uniq[s], 0, 0)),
            pl.BlockSpec((1, max_list), lambda s, uniq: (uniq[s], 0)),
            pl.BlockSpec((1, max_list), lambda s, uniq: (uniq[s], 0)),
            pl.BlockSpec((1, b), lambda s, uniq: (s, 0)),
            pl.BlockSpec((b, d), lambda s, uniq: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((b, k), lambda s, uniq: (0, 0)),
            pl.BlockSpec((b, k), lambda s, uniq: (0, 0)),
        ),
    )
    vals, idx = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ),
        interpret=interpret,
    )(uniq, grouped, grouped_sq, valid, member, queries)
    return vals, idx


def dedup_probes(probes, nlist: int):
    """Compact a (b, nprobe) probe matrix into (uniq, member) for the
    probe-major kernel: uniq (s,) int32 unique list ids (s = min(nlist,
    b*nprobe), tail filled with 0 and masked), member (s, b) float 0/1.

    Pure jnp with static shapes, so it traces into the jitted query step.
    """
    b, nprobe = probes.shape
    slots = min(nlist, b * nprobe)
    flat = jnp.sort(probes.reshape(-1).astype(jnp.int32))
    is_new = jnp.concatenate(
        [jnp.ones((1,), bool), flat[1:] != flat[:-1]])
    pos = jnp.cumsum(is_new) - 1                      # slot of each element
    uniq = jnp.zeros((slots,), jnp.int32).at[pos].set(flat, mode="drop")
    n_uniq = pos[-1] + 1
    slot_live = jnp.arange(slots) < n_uniq
    member = (probes[None, :, :] == uniq[:, None, None]).any(-1)
    member = member & slot_live[:, None]
    return uniq, member.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def ivf_score_topk(grouped, grouped_sq, valid, probes, query, k: int, *,
                   interpret: bool = True):
    """Single-query probed search (batch size 1 of the batched kernel).

    probes: (nprobe,) int32; query: (d,). Returns (vals (k,), flat_ids (k,)).
    """
    vals, idx = ivf_score_topk_batch(
        grouped, grouped_sq, valid, probes[None, :], query[None, :], k,
        interpret=interpret)
    return vals[0], idx[0]
