"""Pallas kernel: IVF probed-slab scoring with scalar-prefetched list ids.

The IVF corpus is stored grouped-by-list as a dense (nlist, max_list, d)
slab array (built once at ``IVFIndex.build`` time). The probe ids selected by
the coarse quantizer are passed as a scalar-prefetch operand so the BlockSpec
index_map can route each grid step's DMA directly to the probed slab — the
TPU idiom for data-dependent gathers (the block-table indirection pattern),
replacing the GPU's per-row gather.

The batched variant runs a (batch, nprobe) grid: the probe dimension is the
inner (sequential) axis, so each query's running top-k accumulates across its
probes while the output block revisits the same (1, k) row. Only
nprobe/nlist of the corpus is ever read per query.

The dedup variant inverts the loop to probe-major: the grid walks the UNIQUE
lists probed by any query in the batch, scoring the whole query batch against
each slab with one MXU matmul and masking queries that did not probe it. A
list shared by many queries is DMA'd from HBM exactly once per batch instead
of once per (query, probe) pair — with batch 64 x nprobe 8 over nlist 64 the
slab traffic drops up to 8x, which is the win that matters on the
bandwidth-bound serving path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fused_score_topk import (_select_topk, _select_topk_pos,
                                            pick_rows, NEG_INF)


def _batch_kernel(probes_ref, slab_ref, sq_ref, valid_ref, q_ref, vals_ref,
                  idx_ref, *, k: int, max_list: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    slab = slab_ref[...][0]            # (max_list, d)
    sq = sq_ref[...][0]                # (max_list,)
    ok = valid_ref[...][0]             # (max_list,) float 0/1
    q = q_ref[...][0]                  # (d,)

    s = 2.0 * jnp.dot(slab, q, preferred_element_type=jnp.float32) - sq
    s = jnp.where(ok > 0.5, s, NEG_INF)[None, :]        # (1, max_list)
    list_id = probes_ref[i, j]
    gids = (list_id * max_list
            + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1))

    cat_v = jnp.concatenate([vals_ref[...], s], axis=-1)
    cat_i = jnp.concatenate([idx_ref[...], gids], axis=-1)
    new_v, new_i = _select_topk(cat_v, cat_i, k)
    vals_ref[...] = new_v.astype(vals_ref.dtype)
    idx_ref[...] = new_i


def _batch_scaled_kernel(probes_ref, slab_ref, sq_ref, sc_ref, valid_ref,
                         q_ref, vals_ref, idx_ref, *, k: int, max_list: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    slab = slab_ref[...][0].astype(jnp.float32)   # (max_list, d) int8 codes
    sq = sq_ref[...][0]                # (max_list,)
    sc = sc_ref[...][0]                # (max_list,) per-row dequant scales
    ok = valid_ref[...][0]             # (max_list,) float 0/1
    q = q_ref[...][0]                  # (d,)

    s = 2.0 * jnp.dot(slab, q, preferred_element_type=jnp.float32) * sc - sq
    s = jnp.where(ok > 0.5, s, NEG_INF)[None, :]        # (1, max_list)
    list_id = probes_ref[i, j]
    gids = (list_id * max_list
            + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1))

    cat_v = jnp.concatenate([vals_ref[...], s], axis=-1)
    cat_i = jnp.concatenate([idx_ref[...], gids], axis=-1)
    new_v, new_i = _select_topk(cat_v, cat_i, k)
    vals_ref[...] = new_v.astype(vals_ref.dtype)
    idx_ref[...] = new_i


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def ivf_score_topk_batch(grouped, grouped_sq, valid, probes, queries, k: int,
                         *, scales=None, interpret: bool = True):
    """Multi-query probed search over the grouped slab layout.

    grouped: (nlist, max_list, d); grouped_sq: (nlist, max_list);
    valid: (nlist, max_list) float 0/1; probes: (b, nprobe) int32;
    queries: (b, d). Returns (vals (b, k), flat_ids (b, k)) with flat ids
    into grouped.reshape(-1, d). Scores are 2<x,q> - ||x||^2 (monotone in
    negative squared distance — the ||q||^2 constant is dropped).
    ``scales`` (nlist, max_list) routes to the int8 variant (per-row dequant
    of the dot output, fp32 accumulation).
    """
    nlist, max_list, d = grouped.shape
    b, nprobe = probes.shape

    probe_slab = pl.BlockSpec((1, max_list, d),
                              lambda i, j, probes: (probes[i, j], 0, 0))
    probe_row = pl.BlockSpec((1, max_list),
                             lambda i, j, probes: (probes[i, j], 0))
    q_spec = pl.BlockSpec((1, d), lambda i, j, probes: (i, 0))
    out_specs = (
        pl.BlockSpec((1, k), lambda i, j, probes: (i, 0)),
        pl.BlockSpec((1, k), lambda i, j, probes: (i, 0)),
    )
    out_shape = (
        jax.ShapeDtypeStruct((b, k), jnp.float32),
        jax.ShapeDtypeStruct((b, k), jnp.int32),
    )
    if scales is None:
        kernel = functools.partial(_batch_kernel, k=k, max_list=max_list)
        in_specs = [probe_slab, probe_row, probe_row, q_spec]
        args = (probes, grouped, grouped_sq, valid, queries)
    else:
        kernel = functools.partial(_batch_scaled_kernel, k=k,
                                   max_list=max_list)
        in_specs = [probe_slab, probe_row, probe_row, probe_row, q_spec]
        args = (probes, grouped, grouped_sq, scales, valid, queries)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, nprobe),
        in_specs=in_specs,
        out_specs=out_specs,
    )
    vals, idx = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    return vals, idx


def _dedup_kernel(uniq_ref, slab_ref, sq_ref, valid_ref, member_ref, q_ref,
                  vals_ref, idx_ref, *, k: int, max_list: int):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    slab = slab_ref[...][0]            # (max_list, d)
    sq = sq_ref[...][0]                # (max_list,)
    ok = valid_ref[...][0]             # (max_list,) float 0/1
    mem = member_ref[...][0]           # (b,) float 0/1: query probed this list
    q = q_ref[...]                     # (b, d)

    scores = 2.0 * jnp.dot(q, slab.T, preferred_element_type=jnp.float32)
    scores = scores - sq[None, :]                       # (b, max_list)
    keep = (ok > 0.5)[None, :] & (mem > 0.5)[:, None]
    scores = jnp.where(keep, scores, NEG_INF)
    list_id = uniq_ref[s]
    gids = (list_id * max_list
            + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1))

    cat_v = jnp.concatenate([vals_ref[...], scores], axis=-1)
    cat_i = jnp.concatenate([idx_ref[...], gids], axis=-1)
    new_v, new_i = _select_topk(cat_v, cat_i, k)
    vals_ref[...] = new_v.astype(vals_ref.dtype)
    idx_ref[...] = new_i


def _dedup_scaled_kernel(uniq_ref, slab_ref, sq_ref, sc_ref, valid_ref,
                         member_ref, q_ref, vals_ref, idx_ref, *, k: int,
                         max_list: int):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    slab = slab_ref[...][0].astype(jnp.float32)   # (max_list, d) int8 codes
    sq = sq_ref[...][0]                # (max_list,)
    sc = sc_ref[...][0]                # (max_list,) per-row dequant scales
    ok = valid_ref[...][0]             # (max_list,) float 0/1
    mem = member_ref[...][0]           # (b,) float 0/1
    q = q_ref[...]                     # (b, d)

    scores = 2.0 * jnp.dot(q, slab.T, preferred_element_type=jnp.float32)
    scores = scores * sc[None, :] - sq[None, :]         # (b, max_list)
    keep = (ok > 0.5)[None, :] & (mem > 0.5)[:, None]
    scores = jnp.where(keep, scores, NEG_INF)
    list_id = uniq_ref[s]
    gids = (list_id * max_list
            + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1))

    cat_v = jnp.concatenate([vals_ref[...], scores], axis=-1)
    cat_i = jnp.concatenate([idx_ref[...], gids], axis=-1)
    new_v, new_i = _select_topk(cat_v, cat_i, k)
    vals_ref[...] = new_v.astype(vals_ref.dtype)
    idx_ref[...] = new_i


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def ivf_score_topk_dedup(grouped, grouped_sq, valid, uniq, member, queries,
                         k: int, *, scales=None, mask=None,
                         interpret: bool = True):
    """Probe-major batched slab search over the deduplicated probed lists.

    grouped: (nlist, max_list, d); grouped_sq/valid: (nlist, max_list);
    uniq: (s,) int32 unique probed list ids (tail slots may repeat a filler
    id — they must have an all-zero ``member`` column); member: (s, b) float
    0/1, 1 iff query b probed list uniq[s]; queries: (b, d).

    Returns (vals (b, k), flat_ids (b, k)) in the same convention as
    ``ivf_score_topk_batch``: scores 2<x,q> - ||x||^2, flat ids into
    grouped.reshape(-1, d). Each unique slab is DMA'd once for the whole
    batch (grid is sequential over slots, queries stay VMEM-resident).
    ``scales`` (nlist, max_list) routes to the int8 variant. ``mask``
    (nlist, max_list) float 0/1 is the filter algebra's candidate mask: it
    multiplies into the validity operand the kernel streams, so ineligible
    rows score -inf inside the scan (exact — both operands are 0/1).
    """
    nlist, max_list, d = grouped.shape
    if mask is not None:
        valid = valid * mask
    b = queries.shape[0]
    slots = uniq.shape[0]

    slab_spec = pl.BlockSpec((1, max_list, d), lambda s, uniq: (uniq[s], 0, 0))
    row_spec = pl.BlockSpec((1, max_list), lambda s, uniq: (uniq[s], 0))
    mem_spec = pl.BlockSpec((1, b), lambda s, uniq: (s, 0))
    q_spec = pl.BlockSpec((b, d), lambda s, uniq: (0, 0))
    out_specs = (
        pl.BlockSpec((b, k), lambda s, uniq: (0, 0)),
        pl.BlockSpec((b, k), lambda s, uniq: (0, 0)),
    )
    out_shape = (
        jax.ShapeDtypeStruct((b, k), jnp.float32),
        jax.ShapeDtypeStruct((b, k), jnp.int32),
    )
    if scales is None:
        kernel = functools.partial(_dedup_kernel, k=k, max_list=max_list)
        in_specs = [slab_spec, row_spec, row_spec, mem_spec, q_spec]
        args = (uniq, grouped, grouped_sq, valid, member, queries)
    else:
        kernel = functools.partial(_dedup_scaled_kernel, k=k,
                                   max_list=max_list)
        in_specs = [slab_spec, row_spec, row_spec, row_spec, mem_spec, q_spec]
        args = (uniq, grouped, grouped_sq, scales, valid, member, queries)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(slots,),
        in_specs=in_specs,
        out_specs=out_specs,
    )
    vals, idx = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    return vals, idx


def _dedup_rows_kernel(uniq_ref, slab_ref, sq_ref, sc_ref, valid_ref,
                       member_ref, pv_ref, pf_ref, q_ref, vals_ref, idx_ref,
                       rv_ref, rf_ref, *, k: int, max_list: int):
    """Rows-returning dedup variant: payload slabs (re-rank vectors and
    filter values, grouped by list like the corpus slab) ride the same
    scalar-prefetch indirection, and the winners' payload rows are carried
    in the output refs via the one-hot copy-through — no HBM gather after
    the kernel. The scale operand is all-ones for fp32/bf16 storage, so
    (vals, ids) stay bit-identical to ``ivf_score_topk_dedup``."""
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idx_ref[...] = jnp.zeros_like(idx_ref)
        rv_ref[...] = jnp.zeros_like(rv_ref)
        rf_ref[...] = jnp.zeros_like(rf_ref)

    slab = slab_ref[...][0].astype(jnp.float32)   # (max_list, d)
    sq = sq_ref[...][0]
    sc = sc_ref[...][0]
    ok = valid_ref[...][0]
    mem = member_ref[...][0]
    q = q_ref[...]                                # (b, d)

    scores = 2.0 * jnp.dot(q, slab.T, preferred_element_type=jnp.float32)
    scores = scores * sc[None, :] - sq[None, :]
    keep = (ok > 0.5)[None, :] & (mem > 0.5)[:, None]
    scores = jnp.where(keep, scores, NEG_INF)
    list_id = uniq_ref[s]
    gids = (list_id * max_list
            + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1))

    run_rv = rv_ref[...]
    run_rf = rf_ref[...]
    cat_v = jnp.concatenate([vals_ref[...], scores], axis=-1)
    cat_i = jnp.concatenate([idx_ref[...], gids], axis=-1)
    new_v, new_i, pos = _select_topk_pos(cat_v, cat_i, k)
    vals_ref[...] = new_v.astype(vals_ref.dtype)
    idx_ref[...] = new_i
    rv_ref[...] = pick_rows(pos, run_rv, pv_ref[...][0], k)
    rf_ref[...] = pick_rows(pos, run_rf, pf_ref[...][0], k)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def ivf_score_topk_dedup_rows(grouped, grouped_sq, valid, uniq, member,
                              queries, payload_v, payload_f, k: int, *,
                              scales=None, interpret: bool = True):
    """Gather-free dedup search: like ``ivf_score_topk_dedup`` but ALSO
    returns the winners' payload rows straight from VMEM.

    payload_v: (nlist, max_list, dv); payload_f: (nlist, max_list, m) —
    grouped row-aligned with the corpus slab. Returns (vals (b, k),
    flat_ids (b, k), rows_v (b, k, dv), rows_f (b, k, m)); unfilled (-inf)
    slots carry zero rows (the caller substitutes its phantom-row payload).
    """
    nlist, max_list, d = grouped.shape
    b = queries.shape[0]
    slots = uniq.shape[0]
    dv = payload_v.shape[-1]
    m = payload_f.shape[-1]
    if scales is None:
        scales = jnp.ones((nlist, max_list), jnp.float32)
    kernel = functools.partial(_dedup_rows_kernel, k=k, max_list=max_list)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(slots,),
        in_specs=[
            pl.BlockSpec((1, max_list, d), lambda s, uniq: (uniq[s], 0, 0)),
            pl.BlockSpec((1, max_list), lambda s, uniq: (uniq[s], 0)),
            pl.BlockSpec((1, max_list), lambda s, uniq: (uniq[s], 0)),
            pl.BlockSpec((1, max_list), lambda s, uniq: (uniq[s], 0)),
            pl.BlockSpec((1, b), lambda s, uniq: (s, 0)),
            pl.BlockSpec((1, max_list, dv), lambda s, uniq: (uniq[s], 0, 0)),
            pl.BlockSpec((1, max_list, m), lambda s, uniq: (uniq[s], 0, 0)),
            pl.BlockSpec((b, d), lambda s, uniq: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((b, k), lambda s, uniq: (0, 0)),
            pl.BlockSpec((b, k), lambda s, uniq: (0, 0)),
            pl.BlockSpec((b, k, dv), lambda s, uniq: (0, 0, 0)),
            pl.BlockSpec((b, k, m), lambda s, uniq: (0, 0, 0)),
        ),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
            jax.ShapeDtypeStruct((b, k, dv), jnp.float32),
            jax.ShapeDtypeStruct((b, k, m), jnp.float32),
        ),
        interpret=interpret,
    )(uniq, grouped, grouped_sq, scales, valid, member, payload_v,
      payload_f, queries)


def dedup_probes(probes, nlist: int):
    """Compact a (b, nprobe) probe matrix into (uniq, member) for the
    probe-major kernel: uniq (s,) int32 unique list ids (s = min(nlist,
    b*nprobe), tail filled with 0 and masked), member (s, b) float 0/1.

    Pure jnp with static shapes, so it traces into the jitted query step.
    """
    b, nprobe = probes.shape
    slots = min(nlist, b * nprobe)
    flat = jnp.sort(probes.reshape(-1).astype(jnp.int32))
    is_new = jnp.concatenate(
        [jnp.ones((1,), bool), flat[1:] != flat[:-1]])
    pos = jnp.cumsum(is_new) - 1                      # slot of each element
    uniq = jnp.zeros((slots,), jnp.int32).at[pos].set(flat, mode="drop")
    n_uniq = pos[-1] + 1
    slot_live = jnp.arange(slots) < n_uniq
    member = (probes[None, :, :] == uniq[:, None, None]).any(-1)
    member = member & slot_live[:, None]
    return uniq, member.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def ivf_score_topk(grouped, grouped_sq, valid, probes, query, k: int, *,
                   scales=None, interpret: bool = True):
    """Single-query probed search (batch size 1 of the batched kernel).

    probes: (nprobe,) int32; query: (d,). Returns (vals (k,), flat_ids (k,)).
    """
    vals, idx = ivf_score_topk_batch(
        grouped, grouped_sq, valid, probes[None, :], query[None, :], k,
        scales=scales, interpret=interpret)
    return vals[0], idx[0]
