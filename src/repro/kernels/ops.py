"""Jit'd public wrappers for the Pallas kernels — the serving dispatch layer.

Every function takes a ``use_pallas`` switch: ``True`` runs the Pallas kernel
(natively compiled on TPU, interpret mode elsewhere), ``False`` runs the
pure-jnp oracle from ``repro.kernels.ref``. The two paths are semantically
identical, so every call site can be A/B-checked (see
``tests/test_parity_pallas.py``).

These wrappers are the *actual* serving path, not a side demo: the index
backends dispatch here when ``FCVIConfig.use_pallas`` is set —

  * ``score_topk``        <- ``repro.index.flat.search`` (fused distance +
    running top-k over streamed corpus blocks),
  * ``ivf_score_topk_batch`` <- ``repro.index.ivf.search`` (scalar-prefetch
    DMA over the grouped (nlist, max_list, d) slab layout, batched over
    queries),
  * ``pq_score_batch``    <- ``repro.index.pq.search`` (one-hot-matmul ADC
    over the residual-PQ combined (coarse, code) LUT),
  * ``rescore``           <- ``repro.core.fcvi.rescore`` / ``multi_probe_query``
    (fused combined-cosine re-ranking),
  * ``fused_transform``   <- offline transform path.

Score conventions: ``score_topk`` returns full negative squared L2;
``ivf_score_topk*`` drops the ``||q||^2`` constant (the caller re-adds it);
``pq_score*`` returns squared distances.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fcvi_transform import fused_transform as _fused_transform
from repro.kernels.fused_score_topk import (score_topk as _score_topk,
                                            score_topk_rows as _score_topk_rows)
from repro.kernels.rescore import rescore as _rescore
from repro.kernels.ivf_score import (dedup_probes,
                                     ivf_score_topk as _ivf_score_topk,
                                     ivf_score_topk_batch as _ivf_score_topk_batch,
                                     ivf_score_topk_dedup as _ivf_score_topk_dedup,
                                     ivf_score_topk_dedup_rows as _ivf_score_topk_dedup_rows)
from repro.kernels.pq_lut import (pq_lut_qdot as _pq_lut_qdot,
                                  pq_score as _pq_score,
                                  pq_score_batch as _pq_score_batch)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fused_transform(v, f, proj, alpha, mean_v, std_v, mean_f, std_f,
                    *, use_pallas: bool = True, block_rows: int = 256):
    """Fused normalize+project+subtract. Rows are zero-padded to the kernel's
    block multiple and sliced back off, so any (n, d)/(n, m) shape works —
    this is what lets the QUERY path (arbitrary batch sizes) dispatch here,
    not just the offline corpus transform."""
    if not use_pallas:
        return ref.ref_fused_transform(v, f, proj, alpha, mean_v, std_v,
                                       mean_f, std_f)
    n = v.shape[0]
    br = min(block_rows, n)
    pad = -n % br
    if pad:
        v = jnp.concatenate([v, jnp.zeros((pad, v.shape[1]), v.dtype)], axis=0)
        f = jnp.concatenate([f, jnp.zeros((pad, f.shape[1]), f.dtype)], axis=0)
    out = _fused_transform(v, f, proj, alpha, mean_v, std_v, mean_f, std_f,
                           block_rows=br, interpret=_interpret())
    return out[:n]


def score_topk(corpus, sq_norms, queries, k, *, scales=None, mask=None,
               use_pallas: bool = True, block_rows: int = 128,
               block_q: int = 64):
    if not use_pallas:
        return ref.ref_score_topk(corpus, sq_norms, queries, k, scales=scales,
                                  mask=mask)
    return _score_topk(corpus, sq_norms, queries, k, scales=scales, mask=mask,
                       block_rows=block_rows, block_q=block_q,
                       interpret=_interpret())


def _pad_corpus(corpus, sq_norms, scales, queries, br, bq, mask=None):
    """Zero-pad corpus rows (+inf squared norms, unit scales, zero mask) and
    queries to tile multiples; pad rows score -inf and never surface."""
    n, d = corpus.shape
    nq = queries.shape[0]
    n_pad = -n % br
    q_pad = -nq % bq
    if n_pad:
        corpus = jnp.concatenate(
            [corpus, jnp.zeros((n_pad, d), corpus.dtype)], axis=0)
        sq_norms = jnp.concatenate(
            [sq_norms, jnp.full((n_pad,), jnp.inf, sq_norms.dtype)])
        if scales is not None:
            scales = jnp.concatenate(
                [scales, jnp.ones((n_pad,), scales.dtype)])
        if mask is not None:
            mask = jnp.concatenate(
                [mask, jnp.zeros((n_pad,), mask.dtype)])
    if q_pad:
        queries = jnp.concatenate(
            [queries, jnp.zeros((q_pad, d), queries.dtype)], axis=0)
    return corpus, sq_norms, scales, queries, mask


def score_topk_padded(corpus, sq_norms, queries, k, *, scales=None, mask=None,
                      use_pallas: bool = True, block_rows: int = 128,
                      block_q: int = 64):
    """``score_topk`` for arbitrary shapes: zero-pads corpus rows (with +inf
    squared norms, so pad rows score -inf and never surface) and queries to
    the kernel's tile multiples, then slices the padding back off. This is
    the dispatch used by flat candidate generation AND the IVF coarse
    quantizer (centroid scoring is just a small score_topk). ``mask`` (n,)
    float 0/1 routes to the filtered kernel variants (ineligible rows score
    -inf inside the scan); pad rows get mask 0."""
    if not use_pallas:
        return ref.ref_score_topk(corpus, sq_norms, queries, k, scales=scales,
                                  mask=mask)
    n = corpus.shape[0]
    nq = queries.shape[0]
    br = min(block_rows, n)
    bq = min(block_q, nq)
    corpus, sq_norms, scales, queries, mask = _pad_corpus(
        corpus, sq_norms, scales, queries, br, bq, mask)
    vals, idx = _score_topk(corpus, sq_norms, queries, k, scales=scales,
                            mask=mask, block_rows=br, block_q=bq,
                            interpret=_interpret())
    return vals[:nq], idx[:nq]


def score_topk_rows_padded(corpus, sq_norms, payload_v, payload_f, queries,
                           k, *, scales=None, use_pallas: bool = True,
                           block_rows: int = 128, block_q: int = 64):
    """Gather-free ``score_topk`` for arbitrary shapes: also returns the
    winners' dequantized scan rows and payload rows straight from the
    kernel's VMEM (see ``fused_score_topk.score_topk_rows``). Padding as in
    ``score_topk_padded``; payload pad rows are zero."""
    if not use_pallas:
        return ref.ref_score_topk_rows(corpus, sq_norms, payload_v, payload_f,
                                       queries, k, scales=scales)
    n = corpus.shape[0]
    nq = queries.shape[0]
    br = min(block_rows, n)
    bq = min(block_q, nq)
    n_pad = -n % br
    if n_pad:
        payload_v = jnp.concatenate(
            [payload_v, jnp.zeros((n_pad, payload_v.shape[1]),
                                  payload_v.dtype)], axis=0)
        payload_f = jnp.concatenate(
            [payload_f, jnp.zeros((n_pad, payload_f.shape[1]),
                                  payload_f.dtype)], axis=0)
    corpus, sq_norms, scales, queries, _ = _pad_corpus(
        corpus, sq_norms, scales, queries, br, bq)
    vals, idx, srows, rv, rf = _score_topk_rows(
        corpus, sq_norms, payload_v, payload_f, queries, k, scales=scales,
        block_rows=br, block_q=bq, interpret=_interpret())
    return vals[:nq], idx[:nq], srows[:nq], rv[:nq], rf[:nq]


def rescore(cand_v, cand_f, qn, fqn, lam, *, use_pallas: bool = True,
            block_b: int = 8):
    """Candidates may arrive bf16 / int8-dequantized: both paths cast to
    fp32 up front so the cosine norms and dots accumulate at full precision
    (a no-op for fp32 inputs)."""
    cand_v = cand_v.astype(jnp.float32)
    cand_f = cand_f.astype(jnp.float32)
    qn = qn.astype(jnp.float32)
    fqn = fqn.astype(jnp.float32)
    if not use_pallas:
        return ref.ref_rescore(cand_v, cand_f, qn, fqn, lam)
    return _rescore(cand_v, cand_f, qn, fqn, lam, block_b=block_b,
                    interpret=_interpret())


def ivf_score_topk(grouped, grouped_sq, valid, probes, query, k, *,
                   scales=None, use_pallas: bool = True):
    if not use_pallas:
        return ref.ref_ivf_score_topk(grouped, grouped_sq, valid > 0.5,
                                      probes, query, k)
    return _ivf_score_topk(grouped, grouped_sq, valid, probes, query, k,
                           scales=scales, interpret=_interpret())


def ivf_score_topk_batch(grouped, grouped_sq, valid, probes, queries, k, *,
                         scales=None, use_pallas: bool = True):
    """Batched probed-slab search: probes (b, nprobe), queries (b, d)."""
    if not use_pallas:
        return ref.ref_ivf_score_topk_batch(grouped, grouped_sq, valid > 0.5,
                                            probes, queries, k, scales=scales)
    return _ivf_score_topk_batch(grouped, grouped_sq, valid, probes, queries,
                                 k, scales=scales, interpret=_interpret())


def ivf_score_topk_dedup(grouped, grouped_sq, valid, uniq, member, queries, k,
                         *, scales=None, mask=None, use_pallas: bool = True):
    """Probe-major deduplicated batched slab search: uniq (s,), member (s, b),
    queries (b, d). Shared lists are DMA'd once per batch (see
    ``ivf_score.dedup_probes`` for building uniq/member from a probe matrix).
    ``mask`` (nlist, max_list) float 0/1 is the filter algebra's candidate
    mask, folded into the validity operand the kernel streams.
    """
    if not use_pallas:
        return ref.ref_ivf_score_topk_dedup(grouped, grouped_sq, valid > 0.5,
                                            uniq, member > 0.5, queries, k,
                                            scales=scales, mask=mask)
    return _ivf_score_topk_dedup(grouped, grouped_sq, valid, uniq, member,
                                 queries, k, scales=scales, mask=mask,
                                 interpret=_interpret())


def ivf_score_topk_dedup_rows(grouped, grouped_sq, valid, uniq, member,
                              queries, payload_v, payload_f, k, *,
                              scales=None, use_pallas: bool = True):
    """Gather-free dedup search: also returns the winners' payload rows
    (re-rank vectors + filter values, grouped row-aligned with the corpus
    slab) straight from the kernel's VMEM. -inf slots carry zero rows."""
    if not use_pallas:
        return ref.ref_ivf_score_topk_dedup_rows(
            grouped, grouped_sq, valid > 0.5, uniq, member > 0.5, queries,
            payload_v, payload_f, k, scales=scales)
    return _ivf_score_topk_dedup_rows(
        grouped, grouped_sq, valid, uniq, member, queries, payload_v,
        payload_f, k, scales=scales, interpret=_interpret())


def pq_score(codes, lut, *, use_pallas: bool = True, block_rows: int = 512):
    if not use_pallas:
        return ref.ref_pq_score(codes, lut)
    return _pq_score(codes, lut, block_rows=block_rows,
                     interpret=_interpret())


def pq_score_batch(codes, luts, *, use_pallas: bool = True,
                   block_rows: int = 256):
    """Multi-query ADC: codes (n, M), luts (q, M, ksub) -> (q, n) scores."""
    if not use_pallas:
        return ref.ref_pq_score_batch(codes, luts)
    return _pq_score_batch(codes, luts, block_rows=block_rows,
                           interpret=_interpret())


def pq_lut_qdot(queries_sub, codebooks, *, use_pallas: bool = True,
                block_q: int = 128):
    """PQ LUT construction's q.codebook cross term — the one matmul that
    dominates ``repro.index.pq.compute_luts``: queries_sub (q, M, dsub) x
    codebooks (M, ksub, dsub) -> (q, M, ksub)."""
    if not use_pallas:
        return ref.ref_pq_lut_qdot(queries_sub, codebooks)
    return _pq_lut_qdot(queries_sub, codebooks, block_q=block_q,
                        interpret=_interpret())
