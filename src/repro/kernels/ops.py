"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run in interpret mode; on TPU they compile
natively. ``use_pallas=False`` falls back to the pure-jnp oracles — the
serving engine exposes this as a config switch so every call site can be
A/B-checked against the reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fcvi_transform import fused_transform as _fused_transform
from repro.kernels.fused_score_topk import score_topk as _score_topk
from repro.kernels.rescore import rescore as _rescore
from repro.kernels.ivf_score import ivf_score_topk as _ivf_score_topk
from repro.kernels.pq_lut import pq_score as _pq_score


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fused_transform(v, f, proj, alpha, mean_v, std_v, mean_f, std_f,
                    *, use_pallas: bool = True, block_rows: int = 256):
    if not use_pallas:
        return ref.ref_fused_transform(v, f, proj, alpha, mean_v, std_v,
                                       mean_f, std_f)
    return _fused_transform(v, f, proj, alpha, mean_v, std_v, mean_f, std_f,
                            block_rows=block_rows, interpret=_interpret())


def score_topk(corpus, sq_norms, queries, k, *, use_pallas: bool = True,
               block_rows: int = 128, block_q: int = 64):
    if not use_pallas:
        return ref.ref_score_topk(corpus, sq_norms, queries, k)
    return _score_topk(corpus, sq_norms, queries, k, block_rows=block_rows,
                       block_q=block_q, interpret=_interpret())


def rescore(cand_v, cand_f, qn, fqn, lam, *, use_pallas: bool = True,
            block_b: int = 8):
    if not use_pallas:
        return ref.ref_rescore(cand_v, cand_f, qn, fqn, lam)
    return _rescore(cand_v, cand_f, qn, fqn, lam, block_b=block_b,
                    interpret=_interpret())


def ivf_score_topk(grouped, grouped_sq, valid, probes, query, k, *,
                   use_pallas: bool = True):
    if not use_pallas:
        return ref.ref_ivf_score_topk(grouped, grouped_sq, valid > 0.5,
                                      probes, query, k)
    return _ivf_score_topk(grouped, grouped_sq, valid, probes, query, k,
                           interpret=_interpret())


def pq_score(codes, lut, *, use_pallas: bool = True, block_rows: int = 512):
    if not use_pallas:
        return ref.ref_pq_score(codes, lut)
    return _pq_score(codes, lut, block_rows=block_rows,
                     interpret=_interpret())
