"""Pallas kernel: fused distance + running top-k — the serving inner loop.

For each (query-tile, corpus-block) cell the kernel computes the negative
squared-L2 scores with one MXU matmul (||q||^2 - 2 q.x + ||x||^2) and merges
them into a running (value, index) top-k that lives in the output refs across
the sequential corpus-block grid dimension. The corpus is therefore streamed
through VMEM exactly once, and no (q x n) score matrix ever exists in HBM —
the k-selection is fused into the scan.

Top-k selection uses an unrolled k-step max/mask sweep (max + iota-argmin)
instead of lax.top_k so every op lowers to plain TPU vector reductions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEF_BLOCK_ROWS = 128
DEF_BLOCK_Q = 64
NEG_INF = float("-inf")


def _select_topk_pos(vals, ids, k: int):
    """Unrolled first-occurrence top-k over the last axis, ALSO returning the
    winners' positions along that axis. vals: (q, c) -> ((q, k),)*3.

    The positions are what the rows-returning kernels key their VMEM row
    copy-through on (position < k = keep the running row, >= k = take row
    ``pos - k`` of the streamed block)."""
    c = vals.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1)
    out_v, out_i, out_p = [], [], []
    cur = vals
    for _ in range(k):
        m = jnp.max(cur, axis=-1, keepdims=True)
        pos = jnp.min(jnp.where(cur == m, iota, c), axis=-1, keepdims=True)
        sel = iota == pos
        out_v.append(m[:, 0])
        out_i.append(jnp.sum(jnp.where(sel, ids, 0), axis=-1))
        out_p.append(pos[:, 0])
        cur = jnp.where(sel, NEG_INF, cur)
    return (jnp.stack(out_v, axis=-1), jnp.stack(out_i, axis=-1),
            jnp.stack(out_p, axis=-1))


def _select_topk(vals, ids, k: int):
    """Unrolled first-occurrence top-k over the last axis. vals: (q, c)."""
    out_v, out_i, _ = _select_topk_pos(vals, ids, k)
    return out_v, out_i


def pick_rows(pos, run_rows, block_rows, k: int):
    """Copy winner rows through VMEM by top-k position (no HBM gather).

    pos: (q, k) positions into the concatenated [running-k | block] axis;
    run_rows: (q, k, d) rows carried so far; block_rows: (bn, d) this grid
    step's payload block. The selection is two one-hot matmuls (exact: each
    output row sums one ``1.0 * x`` with zeros), so it lowers to MXU dots
    instead of a gather.
    """
    q, kk = pos.shape
    bn = block_rows.shape[0]
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (q, kk, k), 2)
    sel_run = (iota_k == pos[:, :, None]).astype(jnp.float32)
    iota_b = jax.lax.broadcasted_iota(jnp.int32, (q, kk, bn), 2)
    sel_blk = (iota_b == (pos[:, :, None] - k)).astype(jnp.float32)
    kept = jax.lax.dot_general(
        sel_run, run_rows, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    taken = jax.lax.dot_general(
        sel_blk, block_rows.astype(jnp.float32), (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return kept + taken


def _kernel(x_ref, xsq_ref, q_ref, qsq_ref, vals_ref, idx_ref, *, k: int,
            block_rows: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    x = x_ref[...]                     # (bn, d)
    q = q_ref[...]                     # (bq, d)
    scores = 2.0 * jnp.dot(q, x.T, preferred_element_type=jnp.float32)
    scores = scores - xsq_ref[...][None, :] - qsq_ref[...][:, None]
    gids = j * block_rows + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)

    run_v = vals_ref[...]
    run_i = idx_ref[...]
    cat_v = jnp.concatenate([run_v, scores], axis=-1)
    cat_i = jnp.concatenate([run_i, gids], axis=-1)
    new_v, new_i = _select_topk(cat_v, cat_i, k)
    vals_ref[...] = new_v.astype(vals_ref.dtype)
    idx_ref[...] = new_i


def _scaled_kernel(x_ref, xsq_ref, scale_ref, q_ref, qsq_ref, vals_ref,
                   idx_ref, *, k: int, block_rows: int):
    """Int8 variant: rows stream as int8 codes, the per-row scale multiplies
    the matmul OUTPUT column — fp32 accumulation, one extra VPU multiply."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    x = x_ref[...].astype(jnp.float32)  # (bn, d)
    q = q_ref[...]                      # (bq, d)
    scores = 2.0 * jnp.dot(q, x.T, preferred_element_type=jnp.float32)
    scores = scores * scale_ref[...][None, :]
    scores = scores - xsq_ref[...][None, :] - qsq_ref[...][:, None]
    gids = j * block_rows + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)

    cat_v = jnp.concatenate([vals_ref[...], scores], axis=-1)
    cat_i = jnp.concatenate([idx_ref[...], gids], axis=-1)
    new_v, new_i = _select_topk(cat_v, cat_i, k)
    vals_ref[...] = new_v.astype(vals_ref.dtype)
    idx_ref[...] = new_i


def _masked_kernel(x_ref, xsq_ref, mask_ref, q_ref, qsq_ref, vals_ref,
                   idx_ref, *, k: int, block_rows: int):
    """Filtered variant: a per-row 0/1 candidate mask streams alongside the
    corpus block and ineligible rows score -inf INSIDE the scan — the filter
    algebra's in-kernel mask plan. One extra (bn,) VPU select per block."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    x = x_ref[...]                     # (bn, d)
    q = q_ref[...]                     # (bq, d)
    scores = 2.0 * jnp.dot(q, x.T, preferred_element_type=jnp.float32)
    scores = scores - xsq_ref[...][None, :] - qsq_ref[...][:, None]
    scores = jnp.where(mask_ref[...][None, :] > 0.5, scores, NEG_INF)
    gids = j * block_rows + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)

    cat_v = jnp.concatenate([vals_ref[...], scores], axis=-1)
    cat_i = jnp.concatenate([idx_ref[...], gids], axis=-1)
    new_v, new_i = _select_topk(cat_v, cat_i, k)
    vals_ref[...] = new_v.astype(vals_ref.dtype)
    idx_ref[...] = new_i


def _masked_scaled_kernel(x_ref, xsq_ref, scale_ref, mask_ref, q_ref, qsq_ref,
                          vals_ref, idx_ref, *, k: int, block_rows: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    x = x_ref[...].astype(jnp.float32)  # (bn, d) int8 codes
    q = q_ref[...]                      # (bq, d)
    scores = 2.0 * jnp.dot(q, x.T, preferred_element_type=jnp.float32)
    scores = scores * scale_ref[...][None, :]
    scores = scores - xsq_ref[...][None, :] - qsq_ref[...][:, None]
    scores = jnp.where(mask_ref[...][None, :] > 0.5, scores, NEG_INF)
    gids = j * block_rows + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)

    cat_v = jnp.concatenate([vals_ref[...], scores], axis=-1)
    cat_i = jnp.concatenate([idx_ref[...], gids], axis=-1)
    new_v, new_i = _select_topk(cat_v, cat_i, k)
    vals_ref[...] = new_v.astype(vals_ref.dtype)
    idx_ref[...] = new_i


def _check_tiling(n, nq, k, block_rows, block_q):
    block_rows = min(block_rows, n)
    block_q = min(block_q, nq)
    if n % block_rows or nq % block_q:
        raise ValueError(
            f"shapes must tile: n={n} %% {block_rows}, q={nq} %% {block_q}")
    if k > n:
        raise ValueError(f"k={k} > corpus size {n}")
    return block_rows, block_q


@functools.partial(jax.jit,
                   static_argnames=("k", "block_rows", "block_q", "interpret"))
def score_topk(corpus, sq_norms, queries, k: int, *, scales=None, mask=None,
               block_rows: int = DEF_BLOCK_ROWS, block_q: int = DEF_BLOCK_Q,
               interpret: bool = True):
    """corpus: (n, d); sq_norms: (n,); queries: (q, d).

    Returns (scores (q, k), ids (q, k)) — negative squared L2, descending.
    ``scales`` (n,) routes to the int8 kernel variant (per-row dequant of the
    matmul output; scores are exact for the dequantized rows). ``mask`` (n,)
    float 0/1 routes to the filtered variants: rows at 0 score -inf inside
    the scan (the in-kernel candidate-mask plan of the filter algebra).
    """
    n, d = corpus.shape
    nq = queries.shape[0]
    block_rows, block_q = _check_tiling(n, nq, k, block_rows, block_q)
    grid = (nq // block_q, n // block_rows)
    qsq = jnp.sum(queries.astype(jnp.float32) ** 2, axis=-1)

    row_spec = pl.BlockSpec((block_rows, d), lambda i, j: (j, 0))
    rsq_spec = pl.BlockSpec((block_rows,), lambda i, j: (j,))
    q_spec = pl.BlockSpec((block_q, d), lambda i, j: (i, 0))
    qsq_spec = pl.BlockSpec((block_q,), lambda i, j: (i,))
    out_specs = (
        pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
        pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
    )
    out_shape = (
        jax.ShapeDtypeStruct((nq, k), jnp.float32),
        jax.ShapeDtypeStruct((nq, k), jnp.int32),
    )
    if scales is None and mask is None:
        kernel = functools.partial(_kernel, k=k, block_rows=block_rows)
        in_specs = [row_spec, rsq_spec, q_spec, qsq_spec]
        args = (corpus, sq_norms, queries, qsq)
    elif scales is None:
        kernel = functools.partial(_masked_kernel, k=k, block_rows=block_rows)
        in_specs = [row_spec, rsq_spec, rsq_spec, q_spec, qsq_spec]
        args = (corpus, sq_norms, mask, queries, qsq)
    elif mask is None:
        kernel = functools.partial(_scaled_kernel, k=k, block_rows=block_rows)
        in_specs = [row_spec, rsq_spec, rsq_spec, q_spec, qsq_spec]
        args = (corpus, sq_norms, scales, queries, qsq)
    else:
        kernel = functools.partial(_masked_scaled_kernel, k=k,
                                   block_rows=block_rows)
        in_specs = [row_spec, rsq_spec, rsq_spec, rsq_spec, q_spec, qsq_spec]
        args = (corpus, sq_norms, scales, mask, queries, qsq)
    vals, idx = pl.pallas_call(
        kernel, grid=grid,
        in_specs=in_specs,
        out_specs=out_specs, out_shape=out_shape, interpret=interpret,
    )(*args)
    return vals, idx


def _rows_kernel(x_ref, xsq_ref, scale_ref, pv_ref, pf_ref, q_ref, qsq_ref,
                 vals_ref, idx_ref, sr_ref, rv_ref, rf_ref, *, k: int,
                 block_rows: int):
    """Rows-returning variant: alongside (vals, ids) the kernel carries the
    winners' DEQUANTIZED scan rows plus their payload rows (re-rank vectors
    and filter values) in the output refs, so the caller never gathers from
    HBM. The per-row scale operand is all-ones for fp32/bf16 storage
    (multiplying by 1.0 is exact, so (vals, ids) match the plain kernel
    bitwise)."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idx_ref[...] = jnp.zeros_like(idx_ref)
        sr_ref[...] = jnp.zeros_like(sr_ref)
        rv_ref[...] = jnp.zeros_like(rv_ref)
        rf_ref[...] = jnp.zeros_like(rf_ref)

    x = x_ref[...].astype(jnp.float32)  # (bn, d)
    scale = scale_ref[...]              # (bn,)
    q = q_ref[...]                      # (bq, d)
    scores = 2.0 * jnp.dot(q, x.T, preferred_element_type=jnp.float32)
    scores = scores * scale[None, :]
    scores = scores - xsq_ref[...][None, :] - qsq_ref[...][:, None]
    gids = j * block_rows + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)

    run_sr = sr_ref[...]
    run_rv = rv_ref[...]
    run_rf = rf_ref[...]
    cat_v = jnp.concatenate([vals_ref[...], scores], axis=-1)
    cat_i = jnp.concatenate([idx_ref[...], gids], axis=-1)
    new_v, new_i, pos = _select_topk_pos(cat_v, cat_i, k)
    vals_ref[...] = new_v.astype(vals_ref.dtype)
    idx_ref[...] = new_i
    sr_ref[...] = pick_rows(pos, run_sr, x * scale[:, None], k)
    rv_ref[...] = pick_rows(pos, run_rv, pv_ref[...], k)
    rf_ref[...] = pick_rows(pos, run_rf, pf_ref[...], k)


@functools.partial(jax.jit,
                   static_argnames=("k", "block_rows", "block_q", "interpret"))
def score_topk_rows(corpus, sq_norms, payload_v, payload_f, queries, k: int,
                    *, scales=None, block_rows: int = DEF_BLOCK_ROWS,
                    block_q: int = DEF_BLOCK_Q, interpret: bool = True):
    """Gather-free flat scan: corpus (n, d); payload_v (n, dv); payload_f
    (n, m); queries (q, d).

    Returns (scores (q, k), ids (q, k), scan_rows (q, k, d) fp32 dequantized
    stored rows for the exact-refine pass, rows_v (q, k, dv), rows_f
    (q, k, m)) — (scores, ids) bit-identical to ``score_topk``.
    """
    n, d = corpus.shape
    nq = queries.shape[0]
    dv = payload_v.shape[-1]
    m = payload_f.shape[-1]
    block_rows, block_q = _check_tiling(n, nq, k, block_rows, block_q)
    grid = (nq // block_q, n // block_rows)
    qsq = jnp.sum(queries.astype(jnp.float32) ** 2, axis=-1)
    if scales is None:
        scales = jnp.ones((n,), jnp.float32)

    kernel = functools.partial(_rows_kernel, k=k, block_rows=block_rows)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_rows,), lambda i, j: (j,)),
            pl.BlockSpec((block_rows,), lambda i, j: (j,)),
            pl.BlockSpec((block_rows, dv), lambda i, j: (j, 0)),
            pl.BlockSpec((block_rows, m), lambda i, j: (j, 0)),
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q,), lambda i, j: (i,)),
        ],
        out_specs=(
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, k, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_q, k, dv), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_q, k, m), lambda i, j: (i, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((nq, k), jnp.float32),
            jax.ShapeDtypeStruct((nq, k), jnp.int32),
            jax.ShapeDtypeStruct((nq, k, d), jnp.float32),
            jax.ShapeDtypeStruct((nq, k, dv), jnp.float32),
            jax.ShapeDtypeStruct((nq, k, m), jnp.float32),
        ),
        interpret=interpret,
    )(corpus, sq_norms, scales, payload_v, payload_f, queries, qsq)
