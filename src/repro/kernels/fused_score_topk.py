"""Pallas kernel: fused distance + running top-k — the serving inner loop.

For each (query-tile, corpus-block) cell the kernel computes the negative
squared-L2 scores with one MXU matmul (||q||^2 - 2 q.x + ||x||^2) and merges
them into a running (value, index) top-k that lives in the output refs across
the sequential corpus-block grid dimension. The corpus is therefore streamed
through VMEM exactly once, and no (q x n) score matrix ever exists in HBM —
the k-selection is fused into the scan.

Top-k selection uses an unrolled k-step max/mask sweep (max + iota-argmin)
instead of lax.top_k so every op lowers to plain TPU vector reductions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEF_BLOCK_ROWS = 128
DEF_BLOCK_Q = 64
NEG_INF = float("-inf")


def _select_topk(vals, ids, k: int):
    """Unrolled first-occurrence top-k over the last axis. vals: (q, c)."""
    c = vals.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1)
    out_v, out_i = [], []
    cur = vals
    for _ in range(k):
        m = jnp.max(cur, axis=-1, keepdims=True)
        pos = jnp.min(jnp.where(cur == m, iota, c), axis=-1, keepdims=True)
        sel = iota == pos
        out_v.append(m[:, 0])
        out_i.append(jnp.sum(jnp.where(sel, ids, 0), axis=-1))
        cur = jnp.where(sel, NEG_INF, cur)
    return jnp.stack(out_v, axis=-1), jnp.stack(out_i, axis=-1)


def _kernel(x_ref, xsq_ref, q_ref, qsq_ref, vals_ref, idx_ref, *, k: int,
            block_rows: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    x = x_ref[...]                     # (bn, d)
    q = q_ref[...]                     # (bq, d)
    scores = 2.0 * jnp.dot(q, x.T, preferred_element_type=jnp.float32)
    scores = scores - xsq_ref[...][None, :] - qsq_ref[...][:, None]
    gids = j * block_rows + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)

    run_v = vals_ref[...]
    run_i = idx_ref[...]
    cat_v = jnp.concatenate([run_v, scores], axis=-1)
    cat_i = jnp.concatenate([run_i, gids], axis=-1)
    new_v, new_i = _select_topk(cat_v, cat_i, k)
    vals_ref[...] = new_v.astype(vals_ref.dtype)
    idx_ref[...] = new_i


@functools.partial(jax.jit,
                   static_argnames=("k", "block_rows", "block_q", "interpret"))
def score_topk(corpus, sq_norms, queries, k: int, *,
               block_rows: int = DEF_BLOCK_ROWS, block_q: int = DEF_BLOCK_Q,
               interpret: bool = True):
    """corpus: (n, d); sq_norms: (n,); queries: (q, d).

    Returns (scores (q, k), ids (q, k)) — negative squared L2, descending.
    """
    n, d = corpus.shape
    nq = queries.shape[0]
    block_rows = min(block_rows, n)
    block_q = min(block_q, nq)
    if n % block_rows or nq % block_q:
        raise ValueError(
            f"shapes must tile: n={n} %% {block_rows}, q={nq} %% {block_q}")
    if k > n:
        raise ValueError(f"k={k} > corpus size {n}")
    grid = (nq // block_q, n // block_rows)
    qsq = jnp.sum(queries.astype(jnp.float32) ** 2, axis=-1)

    kernel = functools.partial(_kernel, k=k, block_rows=block_rows)
    vals, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_rows,), lambda i, j: (j,)),
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q,), lambda i, j: (i,)),
        ],
        out_specs=(
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((nq, k), jnp.float32),
            jax.ShapeDtypeStruct((nq, k), jnp.int32),
        ),
        interpret=interpret,
    )(corpus, sq_norms, queries, qsq)
    return vals, idx
