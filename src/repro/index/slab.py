"""Serving-layout slabs + device-mesh sharding of the index storage layer.

A *slab* is the dense, padded, DMA-friendly materialisation of an index's
serving data — the thing the query hot path actually streams:

  * ``FlatSlab``  — the (n, d) corpus matrix + squared norms (flat backend).
  * ``IVFSlab``   — the grouped (nlist, max_list, d) inverted-list layout +
    the coarse centroids (IVF backend).
  * ``PQSlab``    — the (n, M) residual-PQ codes + coarse assignments, with
    the tiny LUT terms (codebooks, coarse centers, precomputed cross terms)
    kept replicated (PQ backend).

Storage may be fp32, bf16 or int8 (``FCVIConfig.storage_dtype``); the int8
rung additionally carries per-row dequantisation ``scales`` (flat) /
``grouped_scales`` (IVF) which shard alongside the rows they scale. Pad and
sentinel scale entries are 1.0 (a harmless no-op multiplier).

``build_grouped`` materialises the IVF grouped layout from the compact id
lists (moved here from ``repro.index.ivf`` so the layout construction lives
with the layout type).

Each slab has a ``shard(mesh, rules)`` step producing its device-mesh
counterpart:

  * ``FlatSlab.shard``  — ROW-shards the corpus over the mesh axes that the
    ``AxisRules`` "corpus" entry resolves to. ``placement="cluster"`` reuses
    the filter-centric idea of ``index.distributed.cluster_sharded_layout``:
    rows are permuted so whole psi-clusters land on single shards (the
    transformed corpus clusters BY FILTER, so most filtered queries
    concentrate on few shards); ``row_ids`` carries the slab-row -> corpus-id
    map either way, with ``-1`` marking padding rows.
  * ``IVFSlab.shard``   — LIST-shards the grouped layout ("ivf_lists" rule):
    inverted lists ARE the psi-clusters of the transformed corpus, so whole
    lists are greedily packed onto shards balanced by row count
    (``balanced_list_layout``). Each shard additionally carries one sentinel
    (all-invalid) list slot so non-local probes have a harmless local target.

Padding conventions match the kernel dispatch layer (``repro.kernels.ops``):
pad vectors are zero with ``+inf`` squared norms, so they score ``-inf`` on
the matmul-expansion path and are mask-refinable on the exact path.

The sharded slabs are plain host-side containers (NOT pytrees): they hold the
``jax.device_put``-sharded arrays plus the static layout facts (local sizes,
mesh axes) that the ``shard_map`` serving step closes over.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array


# ---------------------------------------------------------------------------
# Grouped-layout materialisation (the IVF serving layout)
# ---------------------------------------------------------------------------

def build_grouped(vectors: Array, sq_norms: Array, lists: Array):
    """Materialise the dense (nlist, max_list, d) serving slabs from id lists.

    ``lists`` is (nlist, max_list) int32 corpus ids with -1 padding. Returns
    (grouped, grouped_sq, valid) with ``valid`` float 0/1 (1 = real row).
    """
    safe = jnp.maximum(lists, 0)
    return (vectors[safe], sq_norms[safe],
            (lists >= 0).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Mesh helpers
# ---------------------------------------------------------------------------

def resolve_axes(mesh: Mesh, rules, name: str) -> Tuple[str, ...]:
    """Mesh axes a logical axis name shards over, per the AxisRules entry."""
    v = rules.rules.get(name)
    if v is None:
        return ()
    axes = v if isinstance(v, tuple) else (v,)
    return tuple(a for a in axes if a in mesh.axis_names)

def axes_size(mesh: Mesh, axes: Sequence[str]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _put(mesh: Mesh, axes: Tuple[str, ...], x: Array) -> Array:
    """Shard dim 0 of ``x`` over ``axes`` (replicated over other mesh axes)."""
    return jax.device_put(x, NamedSharding(mesh, P(axes)))


def pad_dim0(x: Array, to: int, value) -> Array:
    pad = to - x.shape[0]
    if pad <= 0:
        return x
    filler = jnp.full((pad, *x.shape[1:]), value, x.dtype)
    return jnp.concatenate([x, filler], axis=0)


# ---------------------------------------------------------------------------
# Flat slab
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FlatSlab:
    """The flat serving layout: corpus matrix + precomputed squared norms."""

    vectors: Array   # (n, d) fp32 / bf16 / int8 codes
    sq_norms: Array  # (n,)
    scales: Optional[Array] = None  # (n,) fp32 per-row dequant (int8 storage)

    def tree_flatten(self):
        return (self.vectors, self.sq_norms, self.scales), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def size(self) -> int:
        return self.vectors.shape[0]

    def shard(self, mesh: Mesh, rules, *, placement: str = "contiguous",
              centers: Optional[Array] = None,
              rng: Optional[Array] = None,
              attrs: Optional[Array] = None) -> "ShardedFlatSlab":
        """Row-shard this slab over the mesh axes of the "corpus" rule.

        Args: ``mesh`` + an ``AxisRules`` whose "corpus" entry names the mesh
        axes to shard dim 0 over; ``vectors`` may be fp32, bf16 or int8
        codes (the engine's ``storage_dtype`` knob) — sq norms stay fp32
        either way, and int8 storage row-shards its ``scales`` alongside.

        ``placement="contiguous"`` keeps corpus order (bit-compatible with the
        single-device scan); ``"cluster"`` permutes rows so psi-clusters land
        on single shards (filter-centric placement — the transformed corpus
        clusters by filter value, so filtered traffic concentrates per shard).
        ``centers`` optionally fixes the psi-cluster geometry ((ncl, d) fp32,
        e.g. restored from a checkpoint so a restored engine routes
        identically); otherwise a k-means over the stored rows picks
        ``min(4 * n_shards, n)`` centers.

        Cluster placement additionally derives the ROUTING tables consumed by
        the routed serving step (``repro.serve.sharded``): ``router_centers``
        (ncl, d), ``router_radii`` (ncl,) — max distance of a cluster's rows
        to its center, the ball bound used for the exactness check — and the
        ``cluster_to_shard`` incidence (ncl, n_shards) marking every shard
        holding at least one row of each cluster (multi-hot: the load
        balancer may split a cluster's remainder across shards).

        ``attrs`` optionally rides the slab: an (n, m) fp32 RAW attribute
        table, permuted + padded alongside the rows it describes (NaN pad
        rows — NaN compares false under every predicate, so pads are never
        eligible) and sharded the same way, for in-shard predicate
        evaluation by the filtered serving step.
        """
        axes = resolve_axes(mesh, rules, "corpus")
        ns = axes_size(mesh, axes)
        n = self.size
        router_centers = router_radii = cluster_to_shard = None
        if placement == "cluster" and ns > 1:
            from repro.core.clustering import assign, kmeans
            from repro.index.distributed import cluster_sharded_layout

            v32 = self.vectors.astype(jnp.float32)
            if centers is None:
                if rng is None:
                    rng = jax.random.PRNGKey(0)
                centers, _ = kmeans(rng, v32, min(4 * ns, n), iters=5)
            perm, _ = cluster_sharded_layout(v32, centers, ns)
            # the greedy packer balances to exact equal shard loads only when
            # ns divides n; fold any remainder back in corpus order
            if perm.shape[0] < n:
                rest = jnp.setdiff1d(jnp.arange(n), perm, size=n - perm.shape[0])
                perm = jnp.concatenate([perm, rest])
            row_ids = perm.astype(jnp.int32)
            # routing tables, derived from the ACTUAL placement (shard of a
            # row = its slab position // n_local, which also covers rebalanced
            # remainder rows that left their cluster's home shard)
            ncl = centers.shape[0]
            labels = np.asarray(assign(v32, centers))           # corpus order
            c_np = np.asarray(centers, np.float32)
            dist = np.linalg.norm(
                np.asarray(v32, np.float32) - c_np[labels], axis=-1)
            radii = np.zeros((ncl,), np.float32)
            np.maximum.at(radii, labels, dist.astype(np.float32))
            n_local = (n + (-n % ns)) // ns
            perm_np = np.asarray(perm)
            inc = np.zeros((ncl, ns), np.float32)
            inc[labels[perm_np], np.arange(n) // n_local] = 1.0
            router_centers = jnp.asarray(c_np)
            router_radii = jnp.asarray(radii)
            cluster_to_shard = jnp.asarray(inc)
        elif placement == "contiguous" or ns <= 1:
            row_ids = jnp.arange(n, dtype=jnp.int32)
        else:
            raise ValueError(f"unknown placement {placement!r}")
        n_pad = -n % ns
        vec = pad_dim0(self.vectors[row_ids], n + n_pad, 0)
        sq = pad_dim0(self.sq_norms[row_ids], n + n_pad, jnp.inf)
        ids = pad_dim0(row_ids, n + n_pad, -1)
        scales = None
        if self.scales is not None:
            scales = _put(mesh, axes,
                          pad_dim0(self.scales[row_ids], n + n_pad, 1.0))
        attrs_sh = None
        if attrs is not None:
            a32 = jnp.asarray(attrs, jnp.float32)
            attrs_sh = _put(mesh, axes,
                            pad_dim0(a32[row_ids], n + n_pad, jnp.nan))
        return ShardedFlatSlab(
            vectors=_put(mesh, axes, vec),
            sq_norms=_put(mesh, axes, sq),
            row_ids=_put(mesh, axes, ids),
            scales=scales,
            attrs=attrs_sh,
            mesh=mesh, axes=axes, n_real=n,
            n_local=(n + n_pad) // ns, placement=placement,
            router_centers=router_centers, router_radii=router_radii,
            cluster_to_shard=cluster_to_shard,
        )


@dataclasses.dataclass(frozen=True)
class ShardedFlatSlab:
    """Row-sharded flat slab (host-side container, not a pytree).

    The three ``router_*``/``cluster_to_shard`` tables are the routing
    metadata of filter-centric placement; they are only populated for
    ``placement="cluster"`` on a real (>1 shard) mesh and are replicated
    (small: ncl ~ 4 * n_shards).
    """

    vectors: Array        # (n_pad, d) sharded P(axes); zero pad rows
    sq_norms: Array       # (n_pad,) sharded; +inf pad rows
    row_ids: Array        # (n_pad,) sharded int32 corpus ids; -1 pad rows
    mesh: Mesh
    axes: Tuple[str, ...]
    n_real: int
    n_local: int          # rows per shard
    placement: str
    router_centers: Optional[Array] = None   # (ncl, d) fp32 psi-cluster centers
    router_radii: Optional[Array] = None     # (ncl,) fp32 max member distance
    cluster_to_shard: Optional[Array] = None  # (ncl, ns) 0/1 incidence
    scales: Optional[Array] = None  # (n_pad,) sharded fp32; 1.0 pad rows
    attrs: Optional[Array] = None   # (n_pad, m) sharded fp32 RAW attrs;
                                    # NaN pad rows (never predicate-eligible)

    @property
    def n_shards(self) -> int:
        return axes_size(self.mesh, self.axes)


# ---------------------------------------------------------------------------
# IVF slab
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class IVFSlab:
    """The IVF serving layout: coarse centroids + grouped inverted lists."""

    centroids: Array   # (nlist, d)
    lists: Array       # (nlist, max_list) int32 corpus ids, -1 pad
    grouped: Array     # (nlist, max_list, d) fp32 / bf16 / int8 codes
    grouped_sq: Array  # (nlist, max_list)
    valid: Array       # (nlist, max_list) float 0/1
    grouped_scales: Optional[Array] = None  # (nlist, max_list) int8 dequant

    def tree_flatten(self):
        return (self.centroids, self.lists, self.grouped, self.grouped_sq,
                self.valid, self.grouped_scales), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def nlist(self) -> int:
        return self.centroids.shape[0]

    @property
    def max_list(self) -> int:
        return self.lists.shape[1]

    def shard(self, mesh: Mesh, rules, *, placement: str = "balanced",
              list_sizes: Optional[Array] = None,
              attrs: Optional[Array] = None) -> "ShardedIVFSlab":
        """List-shard the grouped layout over the "ivf_lists" rule axes.

        Args: ``mesh`` + an ``AxisRules`` whose "ivf_lists" entry names the
        mesh axes; ``list_sizes`` ((nlist,) int) skips recounting ``valid``.
        The grouped slabs keep their storage dtype (fp32, bf16 or int8 codes
        with ``grouped_scales`` sharded alongside); centroid state stays
        replicated fp32.

        Whole inverted lists (= psi-clusters of the transformed corpus) are
        packed onto shards; ``placement="balanced"`` greedily packs largest
        lists first onto the least-loaded shard (row-count balance, the
        filter-centric analogue of ``cluster_sharded_layout``);
        ``"affinity"`` packs lists with NEARBY centroids onto the same shard
        under balance caps (``distributed.affinity_group_layout`` — the
        placement routed serving wants: a query's co-probed lists share a
        shard, so unprobed shards can skip); ``"contiguous"`` blocks list
        ids in order. Each shard's local block
        carries ``lists_per_shard + 1`` slots — the last is an all-invalid
        sentinel that non-local probes are routed to. The resulting
        ``slot_of_list`` table doubles as the routing table: a probed list's
        owner shard is ``slot_of_list[g] // (lists_per_shard + 1)``
        (``ShardedIVFSlab.list_to_shard``), which the routed serving step
        uses to skip shards owning none of a query's probed lists.

        ``attrs`` optionally rides the slab: an (n, m) fp32 RAW attribute
        table in CORPUS row order, regrouped through ``lists`` into the
        (slot, max_list, m) layout with NaN on pad/sentinel entries (NaN is
        never predicate-eligible) and sharded alongside the rows, for
        in-shard predicate evaluation by the filtered serving step.
        """
        axes = resolve_axes(mesh, rules, "ivf_lists")
        ns = axes_size(mesh, axes)
        nlist, max_list = self.lists.shape
        lp = -(-nlist // ns)              # real list slots per shard
        lpp = lp + 1                      # + sentinel slot
        if list_sizes is None:
            list_sizes = jnp.sum(self.valid > 0.5, axis=-1)
        if placement == "balanced" and ns > 1:
            shard_of, slot_in_shard = balanced_list_layout(
                np.asarray(list_sizes), ns, lp)
        elif placement == "affinity" and ns > 1:
            from repro.index.distributed import affinity_group_layout

            shard_of = affinity_group_layout(
                np.asarray(self.centroids, np.float32),
                np.asarray(list_sizes), ns, slot_capacity=lp)
            slot_in_shard = np.zeros((nlist,), np.int32)
            counts = np.zeros((ns,), np.int32)
            for g in range(nlist):
                slot_in_shard[g] = counts[shard_of[g]]
                counts[shard_of[g]] += 1
        elif placement == "contiguous" or ns <= 1:
            shard_of = np.arange(nlist) // lp
            slot_in_shard = np.arange(nlist) % lp
        else:
            raise ValueError(f"unknown placement {placement!r}")
        slot_of_list = (shard_of * lpp + slot_in_shard).astype(np.int32)

        d = self.grouped.shape[-1]
        grouped = jnp.zeros((ns * lpp, max_list, d), self.grouped.dtype)
        grouped_sq = jnp.full((ns * lpp, max_list), jnp.inf,
                              self.grouped_sq.dtype)
        valid = jnp.zeros((ns * lpp, max_list), self.valid.dtype)
        lists = jnp.full((ns * lpp, max_list), -1, self.lists.dtype)
        slots = jnp.asarray(slot_of_list)
        grouped = grouped.at[slots].set(self.grouped)
        grouped_sq = grouped_sq.at[slots].set(self.grouped_sq)
        valid = valid.at[slots].set(self.valid)
        lists = lists.at[slots].set(self.lists)
        grouped_scales = None
        if self.grouped_scales is not None:
            gs = jnp.ones((ns * lpp, max_list), jnp.float32)
            grouped_scales = _put(mesh, axes,
                                  gs.at[slots].set(self.grouped_scales))
        attrs_sh = None
        if attrs is not None:
            a32 = jnp.asarray(attrs, jnp.float32)
            m = a32.shape[-1]
            ga = jnp.where((self.lists >= 0)[..., None],
                           a32[jnp.maximum(self.lists, 0)],
                           jnp.nan)                    # (nlist, max_list, m)
            full = jnp.full((ns * lpp, max_list, m), jnp.nan, jnp.float32)
            attrs_sh = _put(mesh, axes, full.at[slots].set(ga))
        return ShardedIVFSlab(
            centroids=self.centroids,
            c_sq=jnp.sum(self.centroids.astype(jnp.float32) ** 2, axis=-1),
            slot_of_list=slots,
            grouped=_put(mesh, axes, grouped),
            grouped_sq=_put(mesh, axes, grouped_sq),
            valid=_put(mesh, axes, valid),
            lists=_put(mesh, axes, lists),
            mesh=mesh, axes=axes, nlist=nlist, max_list=max_list,
            lists_per_shard=lp, placement=placement,
            grouped_scales=grouped_scales,
            attrs=attrs_sh,
        )


@dataclasses.dataclass(frozen=True)
class ShardedIVFSlab:
    """List-sharded IVF slab (host-side container, not a pytree)."""

    centroids: Array      # (nlist, d) replicated
    c_sq: Array           # (nlist,) replicated
    slot_of_list: Array   # (nlist,) int32 replicated: storage row of list g
    grouped: Array        # (ns*(lp+1), max_list, d) sharded P(axes)
    grouped_sq: Array     # (ns*(lp+1), max_list) sharded; +inf on sentinels
    valid: Array          # (ns*(lp+1), max_list) sharded; 0 on sentinels
    lists: Array          # (ns*(lp+1), max_list) sharded; -1 on sentinels
    mesh: Mesh
    axes: Tuple[str, ...]
    nlist: int
    max_list: int
    lists_per_shard: int  # real slots per shard (local block adds 1 sentinel)
    placement: str
    grouped_scales: Optional[Array] = None  # sharded; 1.0 on sentinels/pads
    attrs: Optional[Array] = None  # (ns*(lp+1), max_list, m) sharded fp32 RAW
                                   # attrs; NaN on sentinels/pads

    @property
    def n_shards(self) -> int:
        return axes_size(self.mesh, self.axes)

    @property
    def list_to_shard(self) -> Array:
        """(nlist,) int32 shard owning each inverted list — every list is
        wholly owned by one shard, so this routing table is exact (the IVF
        analogue of the flat slab's ``cluster_to_shard`` incidence)."""
        return self.slot_of_list // (self.lists_per_shard + 1)


# ---------------------------------------------------------------------------
# PQ slab
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PQSlab:
    """The residual-PQ serving layout: row-shardable codes + replicated LUTs.

    The ADC scan only ever reads ``codes``/``coarse_ids`` per corpus row —
    everything else (codebooks, coarse centers, the precomputed ``cb_sq`` /
    ``coarse_dot`` cross terms) is LUT state a few KB large, consumed whole
    by ``repro.index.pq.compute_luts``. Sharding therefore ROW-splits only
    the per-row arrays and replicates the LUT terms.
    """

    codebooks: Array       # (M, ksub, dsub) replicated
    codes: Array           # (n, M) uint8/int32 — row-shardable
    coarse_centers: Array  # (ncoarse, d) replicated
    coarse_ids: Array      # (n,) int32 — row-shardable
    cb_sq: Array           # (M, ksub) replicated
    coarse_dot: Array      # (ncoarse, M, ksub) replicated

    def tree_flatten(self):
        return (self.codebooks, self.codes, self.coarse_centers,
                self.coarse_ids, self.cb_sq, self.coarse_dot), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def size(self) -> int:
        return self.codes.shape[0]

    def shard(self, mesh: Mesh, rules, *,
              placement: str = "contiguous") -> "ShardedPQSlab":
        """Row-shard the codes over the "corpus" rule axes (contiguous only:
        PQ has no per-row geometry to cluster by — the coarse quantizer
        already IS the cluster structure, and it rides along replicated).
        Pad rows get code 0 / coarse id 0 and are masked by position
        (``row >= n_real``) in the sharded serving step."""
        if placement != "contiguous":
            raise ValueError(
                f"PQ slab only supports contiguous placement, got "
                f"{placement!r}")
        axes = resolve_axes(mesh, rules, "corpus")
        ns = axes_size(mesh, axes)
        n = self.size
        n_pad = -n % ns
        return ShardedPQSlab(
            codebooks=self.codebooks,
            codes=_put(mesh, axes, pad_dim0(self.codes, n + n_pad, 0)),
            coarse_centers=self.coarse_centers,
            coarse_ids=_put(mesh, axes,
                            pad_dim0(self.coarse_ids, n + n_pad, 0)),
            cb_sq=self.cb_sq,
            coarse_dot=self.coarse_dot,
            mesh=mesh, axes=axes, n_real=n,
            n_local=(n + n_pad) // ns, placement=placement,
        )


@dataclasses.dataclass(frozen=True)
class ShardedPQSlab:
    """Row-sharded PQ slab (host-side container, not a pytree).

    Rows stay in corpus order (contiguous placement), so a slab row's corpus
    id is just its global position — no ``row_ids`` indirection needed."""

    codebooks: Array       # replicated
    codes: Array           # (n_pad, M) sharded P(axes); zero pad rows
    coarse_centers: Array  # replicated
    coarse_ids: Array      # (n_pad,) sharded; zero pad rows
    cb_sq: Array           # replicated
    coarse_dot: Array      # replicated
    mesh: Mesh
    axes: Tuple[str, ...]
    n_real: int
    n_local: int           # rows per shard
    placement: str

    @property
    def n_shards(self) -> int:
        return axes_size(self.mesh, self.axes)


def balanced_list_layout(list_sizes: np.ndarray, n_shards: int,
                         capacity: int):
    """Greedy balanced packing of inverted lists onto shards.

    Largest lists first onto the least-loaded shard that still has a free
    slot (each shard holds at most ``capacity`` lists). The filter-centric
    placement step for IVF: lists are whole psi-clusters, so a probe touches
    exactly one shard. Returns (shard_of_list, slot_in_shard) int arrays.
    """
    sizes = np.asarray(list_sizes, np.int64)
    nlist = sizes.shape[0]
    if n_shards * capacity < nlist:
        raise ValueError(
            f"{n_shards} shards x {capacity} slots < {nlist} lists")
    order = np.argsort(-sizes, kind="stable")
    load = np.zeros(n_shards, np.int64)
    used = np.zeros(n_shards, np.int64)
    shard_of = np.zeros(nlist, np.int32)
    slot_in = np.zeros(nlist, np.int32)
    for g in order:
        free = np.nonzero(used < capacity)[0]
        s = free[np.argmin(load[free])]
        shard_of[g] = s
        slot_in[g] = used[s]
        used[s] += 1
        load[s] += sizes[g]
    return shard_of, slot_in
