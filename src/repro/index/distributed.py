"""Distributed (multi-device / multi-pod) search via shard_map.

The corpus is row-sharded over one or more mesh axes; every device scores its
shard locally (flat or IVF) and the per-shard top-k candidates are merged with
a tree of all-gathers — one merge stage per mesh axis, so cross-pod traffic is
only the (k x devices-per-axis) candidate sets, never raw scores.

Filter-centric placement (beyond-paper): since psi() already arranges the
corpus into filter clusters, we can shard BY cluster so most queries touch a
few shards; `cluster_sharded_layout` computes that permutation and
`routed_search` masks non-probed shards to skip their matmul.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import axis_size, shard_map
from repro.core.clustering import assign
from repro.index import flat as flat_mod

Array = jax.Array


def _local_search(vectors: Array, sq_norms: Array, queries: Array, k: int,
                  row_offset: Array):
    """Exact local top-k with globally valid row ids."""
    q2 = jnp.sum(queries * queries, axis=-1, keepdims=True)
    scores = -(q2 - 2.0 * queries @ vectors.T + sq_norms[None, :])
    vals, idx = jax.lax.top_k(scores, min(k, vectors.shape[0]))
    return vals, idx + row_offset


def merge_over_axis(vals: Array, idx: Array, axis: str, k: int):
    """All-gather candidate sets over one mesh axis and reduce to top-k.

    The shard-aware merge stage: gathers the (n_ax, q, kl) candidate sets of
    every shard along ``axis`` and runs ``flat.merge_topk`` over the pooled
    columns (one concatenation + one top-k), so the merged output inherits
    merge_topk's padding semantics (-inf fill when k exceeds the pool).
    """
    g_vals = jax.lax.all_gather(vals, axis)  # (n_ax, q, kl)
    g_idx = jax.lax.all_gather(idx, axis)
    n_ax = g_vals.shape[0]
    g_vals = jnp.moveaxis(g_vals, 0, -2).reshape(*vals.shape[:-1], n_ax * vals.shape[-1])
    g_idx = jnp.moveaxis(g_idx, 0, -2).reshape(*idx.shape[:-1], n_ax * idx.shape[-1])
    empty_v = g_vals[..., :0]
    empty_i = g_idx[..., :0]
    return flat_mod.merge_topk(g_vals, g_idx, empty_v, empty_i, k)


# internal name kept for existing call sites
_merge_over_axis = merge_over_axis


def tree_merge_topk(vals: Array, idx: Array, axes: Sequence[str],
                    sizes: Sequence[int], k: int):
    """Hierarchical cross-shard top-k merge: one exact merge stage per mesh
    axis (``sizes`` are the static mesh extents of ``axes``). Intermediate
    stages keep min(k, pool) candidates, so the final (replicated) result
    equals the global top-k over every shard's candidate set — the per-shard
    sets only need to contain their local winners."""
    for ax, n_ax in zip(reversed(tuple(axes)), reversed(tuple(sizes))):
        keep = min(k, n_ax * vals.shape[-1])
        vals, idx = merge_over_axis(vals, idx, ax, keep)
    if vals.shape[-1] < k:
        vals, idx = flat_mod.merge_topk(vals, idx, vals[..., :0],
                                        idx[..., :0], k)
    return vals, idx


def sharded_search_fn(mesh: Mesh, shard_axes: Sequence[str], k: int,
                      k_local: int = 0):
    """Build a shard_map'd exact search over a corpus sharded on shard_axes.

    Returns fn(vectors (n,d), sq_norms (n,), queries (q,d)) -> (vals, idx)
    with vectors/sq_norms sharded over rows and queries/output replicated.

    ``k_local`` > 0 truncates per-shard candidate sets before the merge tree
    (candidate-volume /= k/k_local). Statistically safe when k_local well
    exceeds k / n_shards x (merge fan-in): with row-sharded corpora the
    global top-k is spread ~uniformly, so a shard rarely owns more than a
    few winners.
    """
    axes = tuple(shard_axes)
    kl = k_local if k_local and k_local < k else k

    def local_fn(vectors, sq_norms, queries):
        # global row offset of this shard: row-major over the shard axes
        n_local = vectors.shape[0]
        offset = jnp.int32(0)
        stride = n_local
        for ax in reversed(axes):
            offset = offset + jax.lax.axis_index(ax) * stride
            stride = stride * axis_size(ax)
        vals, idx = _local_search(vectors, sq_norms, queries, kl, offset)
        # pad so merges are static even when shards are small
        if vals.shape[-1] < kl:
            pad = kl - vals.shape[-1]
            vals = jnp.pad(vals, ((0, 0), (0, pad)), constant_values=-jnp.inf)
            idx = jnp.pad(idx, ((0, 0), (0, pad)))
        # hierarchical merge: keep k_local until the LAST stage, then k
        for i, ax in enumerate(reversed(axes)):
            keep = k if i == len(axes) - 1 else kl
            vals, idx = _merge_over_axis(vals, idx, ax, keep)
        return vals, idx

    row_spec = P(axes)  # rows sharded over the product of axes
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(row_spec, row_spec, P()),
        out_specs=(P(), P()),
        check_vma=False,
    )


def cluster_sharded_layout(vectors: Array, centroids: Array, n_shards: int):
    """Permutation placing whole clusters on shards (filter-centric placement).

    Returns (perm, shard_of_cluster): ``vectors[perm]`` groups rows so that
    shard s holds the contiguous slice [s*n/n_shards, (s+1)*n/n_shards) and
    clusters are greedily packed (largest first) to balance shard loads.
    """
    import numpy as np

    labels = np.asarray(assign(vectors, centroids))
    n = len(labels)
    nclusters = centroids.shape[0]
    order = np.argsort([-np.sum(labels == c) for c in range(nclusters)])
    shard_load = np.zeros(n_shards, np.int64)
    shard_of_cluster = np.zeros(nclusters, np.int32)
    shard_members: list[list[int]] = [[] for _ in range(n_shards)]
    for c in order:
        members = np.nonzero(labels == c)[0]
        s = int(np.argmin(shard_load))
        shard_of_cluster[c] = s
        shard_load[s] += len(members)
        shard_members[s].extend(members.tolist())
    # round-robin rebalance to exact equal shard sizes (pad via stealing)
    target = n // n_shards
    overflow: list[int] = []
    for s in range(n_shards):
        while len(shard_members[s]) > target:
            overflow.append(shard_members[s].pop())
    for s in range(n_shards):
        while len(shard_members[s]) < target and overflow:
            shard_members[s].append(overflow.pop())
    perm = np.concatenate([np.asarray(m, np.int64) for m in shard_members])
    return jnp.asarray(perm), jnp.asarray(shard_of_cluster)


def routed_search_fn(mesh: Mesh, shard_axes: Sequence[str], k: int):
    """Like sharded_search_fn but each shard is given a per-query probe mask;
    unprobed shards contribute -inf rows (their matmul result is discarded by
    XLA's select; on real hardware the win is realised by the engine batching
    queries per shard-group so unprobed shards run other queries).
    """
    axes = tuple(shard_axes)
    base = sharded_search_fn(mesh, shard_axes, k)  # reuse merge structure

    def local_fn(vectors, sq_norms, queries, probe_mask):
        n_local = vectors.shape[0]
        offset = jnp.int32(0)
        stride = n_local
        shard_lin = jnp.int32(0)
        lin_stride = 1
        for ax in reversed(axes):
            aidx = jax.lax.axis_index(ax)
            offset = offset + aidx * stride
            stride = stride * axis_size(ax)
            shard_lin = shard_lin + aidx * lin_stride
            lin_stride = lin_stride * axis_size(ax)
        vals, idx = _local_search(vectors, sq_norms, queries, k, offset)
        mine = probe_mask[:, shard_lin]  # (q,)
        vals = jnp.where(mine[:, None], vals, -jnp.inf)
        if vals.shape[-1] < k:
            pad = k - vals.shape[-1]
            vals = jnp.pad(vals, ((0, 0), (0, pad)), constant_values=-jnp.inf)
            idx = jnp.pad(idx, ((0, 0), (0, pad)))
        for ax in reversed(axes):
            vals, idx = _merge_over_axis(vals, idx, ax, k)
        return vals, idx

    row_spec = P(axes)
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(row_spec, row_spec, P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )
