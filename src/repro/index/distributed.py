"""Distributed (multi-device / multi-pod) search via shard_map.

The corpus is row-sharded over one or more mesh axes; every device scores its
shard locally (flat or IVF) and the per-shard top-k candidates are merged with
a tree of all-gathers — one merge stage per mesh axis, so cross-pod traffic is
only the (k x devices-per-axis) candidate sets, never raw scores.

Filter-centric placement (beyond-paper): since psi() already arranges the
corpus into filter clusters, we can shard BY cluster so most queries touch a
few shards; `cluster_sharded_layout` computes that permutation and
`routed_search` masks non-probed shards to skip their matmul.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import axis_size, shard_map
from repro.core.clustering import assign
from repro.index import flat as flat_mod

Array = jax.Array


def _local_search(vectors: Array, sq_norms: Array, queries: Array, k: int,
                  row_offset: Array):
    """Exact local top-k with globally valid row ids."""
    q2 = jnp.sum(queries * queries, axis=-1, keepdims=True)
    scores = -(q2 - 2.0 * queries @ vectors.T + sq_norms[None, :])
    vals, idx = jax.lax.top_k(scores, min(k, vectors.shape[0]))
    return vals, idx + row_offset


def linear_shard_index(axes: Sequence[str], sizes: Sequence[int]):
    """This device's linear shard index over the (row-major) product axes.

    The linearization matches how ``PartitionSpec((axes,))`` lays out dim-0
    blocks over the mesh, so ``row // n_local == linear_shard_index`` holds
    for contiguously row-sharded arrays — the ownership convention shared by
    the distributed gather, the tree merge offsets and the shard router.
    Must be called inside a ``shard_map`` body over ``axes``.
    """
    lin = jnp.int32(0)
    stride = 1
    for ax, n_ax in zip(reversed(tuple(axes)), reversed(tuple(sizes))):
        lin = lin + jax.lax.axis_index(ax) * stride
        stride = stride * n_ax
    return lin


def merge_over_axis(vals: Array, idx: Array, axis: str, k: int):
    """All-gather candidate sets over one mesh axis and reduce to top-k.

    The shard-aware merge stage: gathers the (n_ax, q, kl) candidate sets of
    every shard along ``axis`` and runs ``flat.merge_topk`` over the pooled
    columns (one concatenation + one top-k), so the merged output inherits
    merge_topk's padding semantics (-inf fill when k exceeds the pool).
    """
    g_vals = jax.lax.all_gather(vals, axis)  # (n_ax, q, kl)
    g_idx = jax.lax.all_gather(idx, axis)
    n_ax = g_vals.shape[0]
    g_vals = jnp.moveaxis(g_vals, 0, -2).reshape(*vals.shape[:-1], n_ax * vals.shape[-1])
    g_idx = jnp.moveaxis(g_idx, 0, -2).reshape(*idx.shape[:-1], n_ax * idx.shape[-1])
    empty_v = g_vals[..., :0]
    empty_i = g_idx[..., :0]
    return flat_mod.merge_topk(g_vals, g_idx, empty_v, empty_i, k)


# internal name kept for existing call sites
_merge_over_axis = merge_over_axis


def tree_merge_topk(vals: Array, idx: Array, axes: Sequence[str],
                    sizes: Sequence[int], k: int):
    """Hierarchical cross-shard top-k merge: one exact merge stage per mesh
    axis (``sizes`` are the static mesh extents of ``axes``). Intermediate
    stages keep min(k, pool) candidates, so the final (replicated) result
    equals the global top-k over every shard's candidate set — the per-shard
    sets only need to contain their local winners."""
    for ax, n_ax in zip(reversed(tuple(axes)), reversed(tuple(sizes))):
        keep = min(k, n_ax * vals.shape[-1])
        vals, idx = merge_over_axis(vals, idx, ax, keep)
    if vals.shape[-1] < k:
        vals, idx = flat_mod.merge_topk(vals, idx, vals[..., :0],
                                        idx[..., :0], k)
    return vals, idx


def merge_over_axis_rows(vals: Array, idx: Array, rows: Sequence[Array],
                         axis: str, k: int):
    """``merge_over_axis`` that also carries per-candidate PAYLOAD ROWS.

    ``rows`` is a tuple of (..., kl, dim) arrays aligned with the candidate
    axis (e.g. the winners' re-rank vectors and filter values emitted by a
    shard-local scan). The (vals, idx) outputs are computed with exactly the
    same pooled top-k as ``merge_over_axis`` — bit-identical — and every
    rows array is gathered and selected with the same winner positions, so
    the merged candidates arrive WITH their rows and no cross-shard gather
    (mask + psum) is needed afterwards. Pool slots added when ``k`` exceeds
    the pool carry zero rows (matching the -inf / id-0 fill).
    """
    g_vals = jax.lax.all_gather(vals, axis)  # (n_ax, q, kl)
    g_idx = jax.lax.all_gather(idx, axis)
    n_ax = g_vals.shape[0]
    kl = vals.shape[-1]
    total = n_ax * kl
    g_vals = jnp.moveaxis(g_vals, 0, -2).reshape(*vals.shape[:-1], total)
    g_idx = jnp.moveaxis(g_idx, 0, -2).reshape(*idx.shape[:-1], total)
    if k > total:
        pad = k - total
        g_vals = jnp.concatenate(
            [g_vals, jnp.full((*g_vals.shape[:-1], pad), -jnp.inf,
                              g_vals.dtype)], axis=-1)
        g_idx = jnp.concatenate(
            [g_idx, jnp.zeros((*g_idx.shape[:-1], pad), g_idx.dtype)],
            axis=-1)
    top_vals, pos = jax.lax.top_k(g_vals, k)
    top_idx = jnp.take_along_axis(g_idx, pos, axis=-1)
    out_rows = []
    for r in rows:
        g = jax.lax.all_gather(r, axis)      # (n_ax, ..., kl, dim)
        g = jnp.moveaxis(g, 0, -3).reshape(*r.shape[:-2], total, r.shape[-1])
        if k > total:
            g = jnp.concatenate(
                [g, jnp.zeros((*g.shape[:-2], k - total, g.shape[-1]),
                              g.dtype)], axis=-2)
        out_rows.append(jnp.take_along_axis(g, pos[..., None], axis=-2))
    return top_vals, top_idx, tuple(out_rows)


def tree_merge_topk_rows(vals: Array, idx: Array, rows: Sequence[Array],
                         axes: Sequence[str], sizes: Sequence[int], k: int):
    """``tree_merge_topk`` carrying payload rows through every merge stage.

    Same staged reduction (and bit-identical (vals, idx)) as
    ``tree_merge_topk``; the rows ride along via ``merge_over_axis_rows``.
    This is the gather-free alternative to merging ids and then gathering
    rows with a masked psum: the all-gathers here move only (k x fan-in)
    candidate rows per stage, and no all-reduce appears in the trace.
    """
    rows = tuple(rows)
    for ax, n_ax in zip(reversed(tuple(axes)), reversed(tuple(sizes))):
        keep = min(k, n_ax * vals.shape[-1])
        vals, idx, rows = merge_over_axis_rows(vals, idx, rows, ax, keep)
    if vals.shape[-1] < k:
        pad = k - vals.shape[-1]
        vals = jnp.pad(vals, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        idx = jnp.pad(idx, ((0, 0), (0, pad)))
        rows = tuple(jnp.pad(r, ((0, 0), (0, pad), (0, 0))) for r in rows)
    return vals, idx, rows


def sharded_search_fn(mesh: Mesh, shard_axes: Sequence[str], k: int,
                      k_local: int = 0):
    """Build a shard_map'd exact search over a corpus sharded on shard_axes.

    Returns fn(vectors (n,d), sq_norms (n,), queries (q,d)) -> (vals, idx)
    with vectors/sq_norms sharded over rows and queries/output replicated.

    ``k_local`` > 0 truncates per-shard candidate sets before the merge tree
    (candidate-volume /= k/k_local). Statistically safe when k_local well
    exceeds k / n_shards x (merge fan-in): with row-sharded corpora the
    global top-k is spread ~uniformly, so a shard rarely owns more than a
    few winners.
    """
    axes = tuple(shard_axes)
    kl = k_local if k_local and k_local < k else k

    def local_fn(vectors, sq_norms, queries):
        # global row offset of this shard: row-major over the shard axes
        n_local = vectors.shape[0]
        offset = jnp.int32(0)
        stride = n_local
        for ax in reversed(axes):
            offset = offset + jax.lax.axis_index(ax) * stride
            stride = stride * axis_size(ax)
        vals, idx = _local_search(vectors, sq_norms, queries, kl, offset)
        # pad so merges are static even when shards are small
        if vals.shape[-1] < kl:
            pad = kl - vals.shape[-1]
            vals = jnp.pad(vals, ((0, 0), (0, pad)), constant_values=-jnp.inf)
            idx = jnp.pad(idx, ((0, 0), (0, pad)))
        # hierarchical merge: keep k_local until the LAST stage, then k
        for i, ax in enumerate(reversed(axes)):
            keep = k if i == len(axes) - 1 else kl
            vals, idx = _merge_over_axis(vals, idx, ax, keep)
        return vals, idx

    row_spec = P(axes)  # rows sharded over the product of axes
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(row_spec, row_spec, P()),
        out_specs=(P(), P()),
        check_vma=False,
    )


def affinity_group_layout(centers, sizes, n_shards: int,
                          slot_capacity: Optional[int] = None,
                          row_slack: float = 1.3):
    """Shard assignment for groups (psi-clusters / inverted lists) that packs
    NEARBY groups onto the SAME shard, subject to balance caps.

    ``centers``: (ng, d) group centers (numpy/jax, fp32); ``sizes``: (ng,)
    row counts. One region seed per shard is picked with a small k-means over
    the group centers; groups are then placed largest-first onto the nearest
    seed that still has free slot capacity (at most ``slot_capacity`` groups
    per shard) and row headroom (``row_slack`` x the mean shard load); a
    group no shard can take within the row cap falls back to the
    least-loaded shard with a free slot. Returns shard_of_group (ng,) int32.

    This is what makes routed serving skip shards: a query's co-probed
    groups sit in the same region of psi-space, so affinity packing puts
    them on few shards — the pure load-balance packers scatter them and
    every query ends up touching every shard.
    """
    import numpy as np

    from repro.core.clustering import kmeans

    centers = np.asarray(centers, np.float32)
    sizes = np.asarray(sizes, np.int64)
    ng = centers.shape[0]
    if n_shards <= 1:
        return np.zeros((ng,), np.int32)
    if ng <= n_shards:
        return np.arange(ng, dtype=np.int32) % n_shards
    seeds, _ = kmeans(jax.random.PRNGKey(0), jnp.asarray(centers), n_shards,
                      iters=10)
    seeds = np.asarray(seeds)
    d2 = np.sum((centers[:, None, :] - seeds[None]) ** 2, axis=-1)
    cap_rows = int(np.ceil(sizes.sum() / n_shards * row_slack))
    cap_slots = slot_capacity if slot_capacity is not None else ng
    load = np.zeros(n_shards, np.int64)
    used = np.zeros(n_shards, np.int64)
    shard_of = np.zeros(ng, np.int32)
    for g in np.argsort(-sizes, kind="stable"):
        placed = False
        for s in np.argsort(d2[g], kind="stable"):
            if used[s] < cap_slots and load[s] + sizes[g] <= cap_rows:
                shard_of[g] = s
                placed = True
                break
        if not placed:
            free = np.nonzero(used < cap_slots)[0]
            s = free[np.argmin(load[free])]
            shard_of[g] = s
        used[shard_of[g]] += 1
        load[shard_of[g]] += sizes[g]
    return shard_of


def cluster_sharded_layout(vectors: Array, centroids: Array, n_shards: int):
    """Permutation placing whole clusters on shards (filter-centric placement).

    Returns (perm, shard_of_cluster): ``vectors[perm]`` groups rows so that
    shard s holds the contiguous slice [s*n/n_shards, (s+1)*n/n_shards) and
    clusters are packed by CENTER AFFINITY (``affinity_group_layout``:
    nearby psi-clusters co-locate, which is what lets the routed serving
    step skip shards) under a row-load cap, then rebalanced to exact equal
    shard sizes by stealing overflow rows.
    """
    import numpy as np

    labels = np.asarray(assign(vectors, centroids))
    n = len(labels)
    nclusters = centroids.shape[0]
    sizes = np.bincount(labels, minlength=nclusters)
    shard_of_cluster = affinity_group_layout(centroids, sizes, n_shards)
    shard_members: list[list[int]] = [[] for _ in range(n_shards)]
    for c in range(nclusters):
        shard_members[shard_of_cluster[c]].extend(
            np.nonzero(labels == c)[0].tolist())
    # round-robin rebalance to exact equal shard sizes (pad via stealing)
    target = n // n_shards
    overflow: list[int] = []
    for s in range(n_shards):
        while len(shard_members[s]) > target:
            overflow.append(shard_members[s].pop())
    for s in range(n_shards):
        while len(shard_members[s]) < target and overflow:
            shard_members[s].append(overflow.pop())
    perm = np.concatenate([np.asarray(m, np.int64) for m in shard_members])
    return jnp.asarray(perm), jnp.asarray(shard_of_cluster)


def routed_search_fn(mesh: Mesh, shard_axes: Sequence[str], k: int,
                     degraded: bool = False):
    """Like sharded_search_fn but each shard is given a per-query probe mask.

    Per-query routed semantics: a query's candidates come ONLY from shards
    its ``probe_mask`` row selects (unselected shards contribute ``-inf``
    candidate rows). Shards no query in the batch routes to skip their scan
    entirely: the local matmul + top-k runs inside a ``lax.cond`` whose
    predicate is "any query probes me", so an unprobed shard executes the
    zero-work branch instead of a discarded matmul. The serving-engine
    counterpart — router computed in-trace from the slab's placement tables,
    with an exactness bound + dense fallback — is the routed batch step in
    ``repro.serve.sharded``.

    ``degraded=True`` adds one replicated input: an ``alive`` (n_shards,)
    bool mask, ANDed into the cond predicate so a dead shard takes the
    zero-work branch for EVERY query (dead == never-routed) and contributes
    only ``-inf`` rows — shard-loss-tolerant search over the survivors. The
    mask is a traced argument: marking more shards dead never recompiles.
    """
    axes = tuple(shard_axes)
    sizes = tuple(mesh.shape[a] for a in axes)

    def local_fn(vectors, sq_norms, queries, probe_mask, *rest):
        n_local = vectors.shape[0]
        lin = linear_shard_index(axes, sizes)
        offset = lin * n_local
        mine = probe_mask[:, lin]  # (q,)
        pred = jnp.any(mine)
        if degraded:
            alive = rest[0]
            mine = mine & alive[lin]
            pred = pred & alive[lin]
        kl = min(k, n_local)

        def scan(_):
            vals, idx = _local_search(vectors, sq_norms, queries, kl, offset)
            return jnp.where(mine[:, None], vals, -jnp.inf), idx

        def skip(_):
            return (jnp.full((queries.shape[0], kl), -jnp.inf, queries.dtype),
                    jnp.zeros((queries.shape[0], kl), jnp.int32) + offset)

        vals, idx = jax.lax.cond(pred, scan, skip, None)
        if vals.shape[-1] < k:
            pad = k - vals.shape[-1]
            vals = jnp.pad(vals, ((0, 0), (0, pad)), constant_values=-jnp.inf)
            idx = jnp.pad(idx, ((0, 0), (0, pad)))
        for ax in reversed(axes):
            vals, idx = _merge_over_axis(vals, idx, ax, k)
        return vals, idx

    in_specs = (P(axes), P(axes), P(), P())
    if degraded:
        in_specs = in_specs + (P(),)
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(), P()),
        check_vma=False,
    )
