"""Exact (flat) top-k search — the TPU-native 'HNSW replacement'.

Brute-force tiled matmul + running top-k is the roofline-optimal search
primitive on MXU hardware for per-device shards up to ~10M vectors: arithmetic
intensity of the distance matmul is d/2 FLOPs per corpus byte, which is
compute-bound for d >= ~512 at bf16 and keeps the MXU busy, unlike
pointer-chasing graph indexes.

Two candidate-generation paths, selected by ``use_pallas``:

  * jnp (default): one big matmul, or — with ``block_rows`` — a lax.scan that
    streams the corpus in row blocks with a running (value, index) top-k merge
    so the working set stays constant in N.
  * Pallas: ``repro.kernels.ops.score_topk``, the fused distance + running
    top-k kernel (corpus and queries are zero-padded to the kernel's tile
    multiples; padded corpus rows carry +inf squared norms so they score
    -inf and never surface).

Both paths over-retrieve ``k + REFINE_PAD`` candidates and finish with an
exact refinement: the matmul expansion ||q||^2 - 2<q,x> + ||x||^2 loses
~1e-4 absolute precision at fp32 when norms are large (catastrophic
cancellation) and can misorder near-ties, so the retrieved rows are re-scored
with a direct (q - x)^2 pass, which restores exact ordering at O(q*k*d) cost.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.index import quant
from repro.kernels import ops

Array = jax.Array

# extra candidates fetched before the exact-refine pass; absorbs ordering
# flips at the top-k boundary caused by fp32 expansion error
REFINE_PAD = 8


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FlatIndex:
    """Corpus matrix + precomputed squared norms.

    ``scales`` is the int8 storage rung's per-row dequantization scale
    (None for float32/bfloat16 storage): stored rows dequantize as
    ``vectors.astype(f32) * scales[:, None]``.
    """

    vectors: Array   # (n, d) fp32 / bf16 / int8 codes
    sq_norms: Array  # (n,) fp32, of the (dequantized) stored rows
    scales: Optional[Array] = None  # (n,) fp32 per-row scales (int8 only)

    def tree_flatten(self):
        return (self.vectors, self.sq_norms, self.scales), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def size(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    def search(self, queries: Array, k: int, *, use_pallas: bool = False,
               **opts):
        """SearchBackend protocol entry point."""
        return search(self, queries, k, use_pallas=use_pallas, **opts)

    def search_rows(self, queries: Array, k: int, payload_v: Array,
                    payload_f: Array, *, use_pallas: bool = False, **opts):
        """Gather-free SearchBackend entry point (rows, not just ids)."""
        return search_rows(self, queries, k, payload_v, payload_f,
                           use_pallas=use_pallas, **opts)

    def slab(self):
        """The serving-layout view of this index (see ``repro.index.slab``):
        what the mesh-sharding and checkpoint layers consume."""
        from repro.index.slab import FlatSlab

        return FlatSlab(vectors=self.vectors, sq_norms=self.sq_norms,
                        scales=self.scales)


def build(vectors: Array, storage_dtype=None) -> FlatIndex:
    """``storage_dtype`` (bfloat16 or int8) stores the corpus at reduced
    precision for 2x / 4x effective HBM bandwidth on the scan. Squared norms
    are computed in fp32 FROM the stored (cast or dequantized) values, so
    candidate scores are exact for the stored corpus; the exact-refine pass
    then keeps top-k ordering correct w.r.t. the stored rows (accumulation
    stays fp32 throughout). int8 storage additionally carries one fp32
    scale per row (see ``repro.index.quant``)."""
    vectors = jnp.asarray(vectors)
    if quant.is_quantized(storage_dtype):
        codes, scales = quant.quantize_rows(vectors)
        return FlatIndex(vectors=codes, sq_norms=quant.sq_norms_of(codes, scales),
                         scales=scales)
    if storage_dtype is not None:
        vectors = vectors.astype(storage_dtype)
    sq_norms = jnp.sum(vectors.astype(jnp.float32) ** 2, axis=-1)
    return FlatIndex(vectors=vectors, sq_norms=sq_norms)


def merge_topk(vals_a: Array, idx_a: Array, vals_b: Array, idx_b: Array, k: int):
    """Merge two score/index candidate sets into the joint top-k (max-score).

    The merge primitive shared by the blocked scan, the engine's delta merge,
    and the cross-shard tree merge (``distributed.merge_over_axis``), so it
    must stay total over shard-shaped inputs: candidate sets smaller than
    ``k`` (the output is padded with ``-inf`` scores / id 0, matching the
    backend convention for unfillable rows), all-padding inputs (``-inf``
    rows simply lose the merge), and duplicate ids across the two sets (both
    occurrences compete; callers that need set semantics dedup upstream, as
    ``multi_probe_query`` does — the engine's shard/delta id spaces are
    disjoint by construction).
    """
    vals = jnp.concatenate([vals_a, vals_b], axis=-1)
    idxs = jnp.concatenate([idx_a, idx_b], axis=-1)
    total = vals.shape[-1]
    if k > total:
        pad = k - total
        vals = jnp.concatenate(
            [vals, jnp.full((*vals.shape[:-1], pad), -jnp.inf, vals.dtype)],
            axis=-1)
        idxs = jnp.concatenate(
            [idxs, jnp.zeros((*idxs.shape[:-1], pad), idxs.dtype)], axis=-1)
    top_vals, pos = jax.lax.top_k(vals, k)
    return top_vals, jnp.take_along_axis(idxs, pos, axis=-1)


def _exact_refine(vectors: Array, queries: Array, cand_idx: Array, k: int,
                  mask: Optional[Array] = None,
                  scales: Optional[Array] = None):
    """Re-score gathered candidates with a direct (q - x)^2 pass, top-k.

    Runs in fp32 regardless of the storage dtype: bf16-stored rows are cast
    up and int8 rows are dequantized with their per-row ``scales``, so the
    refined ordering is exact w.r.t. the stored corpus."""
    rows = vectors[cand_idx].astype(jnp.float32)              # (q, kk, d)
    if scales is not None:
        rows = rows * scales[cand_idx][..., None]
    d2 = jnp.sum((queries[:, None, :] - rows) ** 2, axis=-1)
    if mask is not None:
        d2 = jnp.where(mask[cand_idx], d2, jnp.inf)
    vals, pos = jax.lax.top_k(-d2, k)
    return vals, jnp.take_along_axis(cand_idx, pos, axis=-1)


def _refine_carried(scan_rows: Array, queries: Array, k: int):
    """Exact refine over KERNEL-CARRIED candidate rows (already dequantized
    fp32): same arithmetic as ``_exact_refine``, minus the HBM gather.
    Returns (vals, pos) — pos indexes the carried candidate axis."""
    d2 = jnp.sum((queries[:, None, :] - scan_rows) ** 2, axis=-1)
    return jax.lax.top_k(-d2, k)


def _pallas_candidates(index: FlatIndex, queries: Array, kk: int,
                       block_rows: int = 128, block_q: int = 64) -> Array:
    """Candidate ids via the fused Pallas kernel (padding handled by ops)."""
    _, idx = ops.score_topk_padded(index.vectors, index.sq_norms, queries, kk,
                                   block_rows=block_rows, block_q=block_q,
                                   scales=index.scales)
    return idx


@partial(jax.jit, static_argnames=("k", "block_rows", "use_pallas"))
def search(index: FlatIndex, queries: Array, k: int, block_rows: int = 0,
           *, use_pallas: bool = False):
    """Top-k by squared-L2 (returned as NEGATIVE distance = score).

    queries: (q, d). Returns (scores (q,k), indices (q,k)).
    ``use_pallas`` routes candidate generation through the fused kernel.
    On the jnp path, ``block_rows`` > 0 streams the corpus in blocks of that
    many rows with a running top-k (bounded memory); 0 scores everything at
    once.
    """
    n = index.size
    k_out = min(k, n)
    kk = min(n, k_out + REFINE_PAD)

    if use_pallas:
        cand = _pallas_candidates(index, queries, kk)
        return _exact_refine(index.vectors, queries, cand, k_out,
                             scales=index.scales)

    q2 = jnp.sum(queries * queries, axis=-1, keepdims=True)

    def score_block(rows: Array, row_sq: Array,
                    row_scale: Optional[Array] = None) -> Array:
        # negative squared distance (higher is better); the per-row int8
        # scale multiplies the matmul OUTPUT column (same formula as the
        # Pallas kernel, so pallas/jnp stay in lockstep)
        dot = queries @ rows.astype(queries.dtype).T
        if row_scale is not None:
            dot = dot * row_scale[None, :]
        return -(q2 - 2.0 * dot + row_sq[None, :])

    if block_rows <= 0 or block_rows >= n:
        scores = score_block(index.vectors, index.sq_norms, index.scales)
        _, cand = jax.lax.top_k(scores, kk)
        return _exact_refine(index.vectors, queries, cand, k_out,
                             scales=index.scales)

    if n % block_rows != 0:
        raise ValueError(f"block_rows={block_rows} must divide n={n}")
    nblk = n // block_rows
    vecs = index.vectors.reshape(nblk, block_rows, index.dim)
    sqs = index.sq_norms.reshape(nblk, block_rows)
    scls = (None if index.scales is None
            else index.scales.reshape(nblk, block_rows))
    kb = min(kk, block_rows)

    def body(carry, blk):
        run_vals, run_idx = carry
        rows, row_sq, row_scale, blk_id = blk
        s = score_block(rows, row_sq, row_scale)
        v, i = jax.lax.top_k(s, kb)
        i = i + blk_id * block_rows
        return merge_topk(run_vals, run_idx, v, i, kk), None

    init_vals = jnp.full((queries.shape[0], kk), -jnp.inf, queries.dtype)
    init_idx = jnp.zeros((queries.shape[0], kk), jnp.int32)
    blk_ids = jnp.arange(nblk)
    if scls is None:
        def body_ns(carry, blk):
            rows, row_sq, blk_id = blk
            return body(carry, (rows, row_sq, None, blk_id))
        (_, cand), _ = jax.lax.scan(
            body_ns, (init_vals, init_idx), (vecs, sqs, blk_ids))
    else:
        (_, cand), _ = jax.lax.scan(
            body, (init_vals, init_idx), (vecs, sqs, scls, blk_ids))
    return _exact_refine(index.vectors, queries, cand, k_out,
                         scales=index.scales)


@partial(jax.jit, static_argnames=("k", "use_pallas"))
def search_rows(index: FlatIndex, queries: Array, k: int, payload_v: Array,
                payload_f: Array, *, use_pallas: bool = False):
    """Gather-free top-k: returns the winners' PAYLOAD ROWS with the ids.

    payload_v (n, dv) / payload_f (n, m) are row-aligned with the corpus
    (for serving: the normalized originals used by combined-score re-rank).
    Returns (scores (q,k), ids (q,k), rows_v (q,k,dv), rows_f (q,k,m)) with
    (scores, ids) bit-identical to ``search``. On the Pallas path the rows
    ride out of the scoring kernel's VMEM (no HBM gather); the jnp reference
    path gathers by id, which is the semantic definition of the output.
    """
    n = index.size
    k_out = min(k, n)
    kk = min(n, k_out + REFINE_PAD)

    if use_pallas:
        _, cand, scan_rows, rows_v, rows_f = ops.score_topk_rows_padded(
            index.vectors, index.sq_norms, payload_v, payload_f, queries, kk,
            scales=index.scales)
        vals, pos = _refine_carried(scan_rows, queries, k_out)
        ids = jnp.take_along_axis(cand, pos, axis=-1)
        rows_v = jnp.take_along_axis(rows_v, pos[..., None], axis=1)
        rows_f = jnp.take_along_axis(rows_f, pos[..., None], axis=1)
        return vals, ids, rows_v, rows_f

    vals, ids = search(index, queries, k, use_pallas=False)
    return vals, ids, payload_v[ids], payload_f[ids]


@partial(jax.jit, static_argnames=("k", "use_pallas"))
def search_masked(index: FlatIndex, queries: Array, k: int, mask: Array,
                  *, use_pallas: bool = False):
    """Exact search restricted to ``mask`` (pre-filtering primitive).

    mask: (n,) bool — True rows are eligible. Ineligible rows score -inf.
    ``use_pallas`` routes candidate generation through the masked variant of
    the fused scan kernel (the mask rides in as a kernel operand).
    """
    n = index.size
    k_out = min(k, n)
    kk = min(n, k_out + REFINE_PAD)
    if use_pallas:
        _, cand = ops.score_topk_padded(
            index.vectors, index.sq_norms, queries, kk, scales=index.scales,
            mask=mask.astype(jnp.float32))
    else:
        q2 = jnp.sum(queries * queries, axis=-1, keepdims=True)
        dot = queries @ index.vectors.astype(queries.dtype).T
        if index.scales is not None:
            dot = dot * index.scales[None, :]
        scores = -(q2 - 2.0 * dot + index.sq_norms[None, :])
        scores = jnp.where(mask[None, :], scores, -jnp.inf)
        _, cand = jax.lax.top_k(scores, kk)
    vals, idx = _exact_refine(index.vectors, queries, cand, k_out, mask=mask,
                              scales=index.scales)
    return jnp.where(jnp.isinf(vals), -jnp.inf, vals), idx


# ---------------------------------------------------------------------------
# Filtered refine: the shared exactness anchor of the filter-algebra plans
# ---------------------------------------------------------------------------
#
# Every physical plan (psi fold / in-kernel mask / routed pruning, meshless
# or sharded) finishes through these primitives, which compute per-row fp32
# squared distances with ONE canonical elementwise expression and break ties
# deterministically by (distance, id). Identical candidate rows therefore
# produce identical bits under every plan and topology — candidate
# generation only has to guarantee the true filtered top-k is IN the
# candidate set, never how it is ordered.

#: id sentinel for dead (ineligible / unfilled) slots while sorting; maps to
#: -1 in the final output. Sorts after every real id at equal key.
DEAD_ID = jnp.iinfo(jnp.int32).max


def filtered_d2(queries: Array, rows: Array) -> Array:
    """Canonical fp32 squared distance: queries (b, d) x rows (b, c, d) or
    (c, d) -> (b, c). Pure elementwise subtract/multiply + minor-axis sum —
    no dot_general — so every plan computes the same bits for the same row.
    """
    if rows.ndim == 2:
        rows = rows[None, :, :]
    diff = queries[:, None, :].astype(jnp.float32) - rows
    return jnp.sum(diff * diff, axis=-1)


def lexsort_topk(d2: Array, ids: Array, k: int):
    """Smallest-k by (d2 asc, id asc) along the last axis; pads with
    (+inf, DEAD_ID) when fewer than ``k`` entries exist."""
    c = d2.shape[-1]
    if c < k:
        pad = k - c
        d2 = jnp.concatenate(
            [d2, jnp.full((*d2.shape[:-1], pad), jnp.inf, d2.dtype)], axis=-1)
        ids = jnp.concatenate(
            [ids, jnp.full((*ids.shape[:-1], pad), DEAD_ID, ids.dtype)],
            axis=-1)
    d2s, idss = jax.lax.sort((d2, ids), dimension=-1, num_keys=2)
    return d2s[..., :k], idss[..., :k]


def finalize_filtered(d2: Array, ids: Array):
    """(d2, ids) -> (scores, ids) in the filtered-result convention:
    scores = -d2, dead slots = (-inf, -1)."""
    dead = jnp.isinf(d2)
    return (jnp.where(dead, -jnp.inf, -d2),
            jnp.where(dead, jnp.int32(-1), ids))


def masked_candidates(index: FlatIndex, queries: Array, kk: int, elig: Array,
                      *, use_pallas: bool = False):
    """Masked-scan candidate generation for the filter algebra's mask plan:
    the (n,) eligibility mask rides into the fused kernel as an operand, so
    ineligible rows score -inf inside the scan. Returns (cand (b, kk) corpus
    ids, valid (b, kk) bool) for ``filtered_refine``."""
    vals, cand = ops.score_topk_padded(
        index.vectors, index.sq_norms, queries, kk, scales=index.scales,
        mask=elig.astype(jnp.float32), use_pallas=use_pallas)
    return jnp.maximum(cand, 0), ~jnp.isneginf(vals)


def filtered_refine(vectors: Array, scales: Optional[Array], queries: Array,
                    cand_idx: Array, cand_valid: Array, elig: Array, k: int):
    """Exact filtered top-k over a candidate set.

    cand_idx: (b, c) corpus ids (valid entries must be duplicate-free);
    cand_valid: (b, c) bool (False = unfilled scan slot); elig: (n,) bool
    row eligibility. Ineligible/invalid candidates get (+inf, DEAD_ID) and
    the survivors sort by (exact fp32 d2, id). Returns (d2 (b, k),
    ids (b, k)) — callers finish with ``finalize_filtered``.
    """
    rows = vectors[cand_idx].astype(jnp.float32)              # (b, c, d)
    if scales is not None:
        rows = rows * scales[cand_idx][..., None]
    d2 = filtered_d2(queries, rows)
    ok = cand_valid & elig[cand_idx]
    d2 = jnp.where(ok, d2, jnp.inf)
    ids = jnp.where(ok, cand_idx.astype(jnp.int32), DEAD_ID)
    return lexsort_topk(d2, ids, k)
