"""Exact (flat) top-k search — the TPU-native 'HNSW replacement'.

Brute-force tiled matmul + running top-k is the roofline-optimal search
primitive on MXU hardware for per-device shards up to ~10M vectors: arithmetic
intensity of the distance matmul is d/2 FLOPs per corpus byte, which is
compute-bound for d >= ~512 at bf16 and keeps the MXU busy, unlike
pointer-chasing graph indexes. The corpus is streamed through VMEM in row
blocks with a running (value, index) top-k merge so the working set stays
constant in N.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FlatIndex:
    """Corpus matrix + precomputed squared norms."""

    vectors: Array   # (n, d)
    sq_norms: Array  # (n,)

    def tree_flatten(self):
        return (self.vectors, self.sq_norms), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def size(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]


def build(vectors: Array) -> FlatIndex:
    vectors = jnp.asarray(vectors)
    return FlatIndex(vectors=vectors, sq_norms=jnp.sum(vectors * vectors, axis=-1))


def merge_topk(vals_a: Array, idx_a: Array, vals_b: Array, idx_b: Array, k: int):
    """Merge two (..., >=k) score/index sets into the joint top-k (max-score)."""
    vals = jnp.concatenate([vals_a, vals_b], axis=-1)
    idxs = jnp.concatenate([idx_a, idx_b], axis=-1)
    top_vals, pos = jax.lax.top_k(vals, k)
    return top_vals, jnp.take_along_axis(idxs, pos, axis=-1)


@partial(jax.jit, static_argnames=("k", "block_rows"))
def search(index: FlatIndex, queries: Array, k: int, block_rows: int = 0):
    """Top-k by squared-L2 (returned as NEGATIVE distance = score).

    queries: (q, d). Returns (scores (q,k), indices (q,k)).
    ``block_rows`` > 0 streams the corpus in blocks of that many rows with a
    running top-k (bounded memory); 0 scores everything at once.
    """
    n = index.size
    q2 = jnp.sum(queries * queries, axis=-1, keepdims=True)

    def score_block(rows: Array, row_sq: Array) -> Array:
        # negative squared distance (higher is better)
        return -(q2 - 2.0 * queries @ rows.T + row_sq[None, :])

    if block_rows <= 0 or block_rows >= n:
        scores = score_block(index.vectors, index.sq_norms)
        vals, idx = jax.lax.top_k(scores, min(k, n))
        return vals, idx

    if n % block_rows != 0:
        raise ValueError(f"block_rows={block_rows} must divide n={n}")
    nblk = n // block_rows
    vecs = index.vectors.reshape(nblk, block_rows, index.dim)
    sqs = index.sq_norms.reshape(nblk, block_rows)
    kk = min(k, block_rows)

    def body(carry, blk):
        run_vals, run_idx = carry
        rows, row_sq, blk_id = blk
        s = score_block(rows, row_sq)
        v, i = jax.lax.top_k(s, kk)
        i = i + blk_id * block_rows
        return merge_topk(run_vals, run_idx, v, i, k), None

    init_vals = jnp.full((queries.shape[0], k), -jnp.inf, queries.dtype)
    init_idx = jnp.zeros((queries.shape[0], k), jnp.int32)
    (vals, idx), _ = jax.lax.scan(
        body, (init_vals, init_idx), (vecs, sqs, jnp.arange(nblk))
    )
    return vals, idx


@partial(jax.jit, static_argnames=("k",))
def search_masked(index: FlatIndex, queries: Array, k: int, mask: Array):
    """Exact search restricted to ``mask`` (pre-filtering primitive).

    mask: (n,) bool — True rows are eligible. Ineligible rows score -inf.
    """
    q2 = jnp.sum(queries * queries, axis=-1, keepdims=True)
    scores = -(q2 - 2.0 * queries @ index.vectors.T + index.sq_norms[None, :])
    scores = jnp.where(mask[None, :], scores, -jnp.inf)
    return jax.lax.top_k(scores, min(k, index.size))
