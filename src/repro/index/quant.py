"""Per-row symmetric int8 quantization for corpus slabs.

The int8 rung of the storage-dtype ladder (``FCVIConfig.storage_dtype``):
each corpus row is stored as int8 codes plus ONE fp32 scale, chosen so the
row's max-magnitude element maps to +-127. Scoring kernels stream the int8
codes (quarter the HBM traffic of fp32) and dequantize in VMEM after the
load — the per-row scale multiplies the matmul OUTPUT column, so the
accumulation stays fp32 and the scores are exact for the DEQUANTIZED rows:

    2 <q, s * x8> = 2 s (q . x8)    (one extra VPU multiply per score)

Squared norms are fp32 computed from the dequantized values, matching the
bf16 rung's convention (scores exact w.r.t. the stored corpus), and the
exact-refine / combined-score re-rank stages always run on fp32 rows, so the
final top-k matches the fp32 reference (see ``docs/architecture.md``,
"Quantization ladder").

Edge cases handled here (and pinned by ``tests/test_quantization.py``):
  * constant / all-zero rows: a zero value range would produce a 0 scale and
    0/0 codes — the scale is clamped to 1.0 (codes are exactly 0 either way);
  * saturating outlier rows: the scale is derived FROM the row max, so
    ``|x / scale| <= 127`` by construction and the round never clips;
  * empty slabs: shape-(0, d) inputs quantize to shape-(0,) scales.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

# int8 symmetric range: scale maps the row's absolute max onto +-127
QMAX = 127.0


def quantize_rows(x: Array):
    """Quantize rows of ``x`` (..., d) fp32 to (codes int8, scales fp32).

    ``scales`` has shape ``x.shape[:-1]`` — one scale per row, broadcast over
    the feature axis. Rows with zero value range (constant-zero rows, or the
    all-zero padding rows of grouped slabs) get scale 1.0 so dequantization
    stays finite; their codes are exactly zero.
    """
    x = jnp.asarray(x, jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1)
    scales = jnp.where(amax > 0.0, amax / QMAX, 1.0).astype(jnp.float32)
    codes = jnp.round(x / scales[..., None]).astype(jnp.int8)
    return codes, scales


def dequantize_rows(codes: Array, scales: Array) -> Array:
    """(codes (..., d) int8, scales (...,) fp32) -> fp32 rows.

    This is the ONE dequantization formula shared by every consumer (jnp
    reference scoring, the Pallas kernels' VMEM casts, exact refine and the
    checkpoint restore path), so the rungs stay bit-identical to each other:
    ``codes.astype(f32) * scale``.
    """
    return codes.astype(jnp.float32) * scales[..., None]


def sq_norms_of(codes: Array, scales: Array) -> Array:
    """fp32 squared norms of the dequantized rows (the slab's sq_norms)."""
    return jnp.sum(dequantize_rows(codes, scales) ** 2, axis=-1)


def is_quantized(dtype) -> bool:
    """True for storage dtypes that carry per-row scales."""
    return dtype is not None and jnp.dtype(dtype) == jnp.dtype(jnp.int8)
