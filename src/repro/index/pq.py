"""Residual Product Quantization index with ADC (asymmetric distance) scoring.

TPU adaptation of the paper's third backend (ANNOY slot), upgraded to the
IVF-ADC recipe: a coarse k-means quantizer captures the between-cluster
structure of the corpus and PQ encodes only the RESIDUAL (x - coarse_center),
so the subspace codebooks spend their resolution on within-cluster geometry.
On clustered corpora this cuts reconstruction error by ~2x versus plain PQ
and is what makes ADC candidates good enough for the exact re-ranker
(FCVI's rescore stage).

Each vector is stored as one coarse id + M int8-range codes; queries build an
(ncoarse, M, ksub) LUT of subspace distances (one (M, ksub) table per coarse
center, since the residual depends on it) and score each corpus row with a
gather-accumulate over its codes — a memory-bound sweep at ~M bytes/row
instead of 4d. With ``use_pallas`` the sweep runs through
``repro.kernels.ops.pq_score_batch``: the per-row coarse indirection is
folded into a combined (coarse, code) index so the kernel's one-hot-matmul
ADC applies unchanged over a flattened (M, ncoarse*ksub) LUT.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.clustering import kmeans, assign
from repro.kernels import ops

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PQIndex:
    codebooks: Array       # (M, ksub, dsub) residual codebooks
    codes: Array           # (n, M) in [0, ksub): uint8 when ksub <= 256
    coarse_centers: Array  # (ncoarse, d)
    coarse_ids: Array      # (n,) int32 in [0, ncoarse)
    cb_sq: Array           # (M, ksub) ||codebook||^2 (precomputed at build)
    coarse_dot: Array      # (ncoarse, M, ksub) center_m . codebook (build)

    def tree_flatten(self):
        return (self.codebooks, self.codes, self.coarse_centers,
                self.coarse_ids, self.cb_sq, self.coarse_dot), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def size(self) -> int:
        return self.codes.shape[0]

    @property
    def n_subspaces(self) -> int:
        return self.codebooks.shape[0]

    @property
    def ksub(self) -> int:
        return self.codebooks.shape[1]

    @property
    def ncoarse(self) -> int:
        return self.coarse_centers.shape[0]

    def search(self, queries: Array, k: int, *, use_pallas: bool = False,
               **opts):
        """SearchBackend protocol entry point."""
        return search(self, queries, k, use_pallas=use_pallas, **opts)

    def slab(self):
        """The serving-layout view of this index (see ``repro.index.slab``):
        replicated LUT terms + row-shardable codes, what the mesh-sharding
        layer consumes."""
        from repro.index.slab import PQSlab

        return PQSlab(codebooks=self.codebooks, codes=self.codes,
                      coarse_centers=self.coarse_centers,
                      coarse_ids=self.coarse_ids, cb_sq=self.cb_sq,
                      coarse_dot=self.coarse_dot)


def build(vectors: Array, m_subspaces: int = 8, ksub: int = 256,
          rng: Array | None = None, iters: int = 15,
          ncoarse: int = 32) -> PQIndex:
    vectors = jnp.asarray(vectors, jnp.float32)
    n, d = vectors.shape
    if d % m_subspaces:
        raise ValueError(f"d={d} must be divisible by M={m_subspaces}")
    dsub = d // m_subspaces
    ksub = min(ksub, n)
    ncoarse = max(1, min(ncoarse, n))
    if rng is None:
        rng = jax.random.PRNGKey(0)
    coarse_key, *keys = jax.random.split(rng, m_subspaces + 1)

    coarse_centers, coarse_ids = kmeans(coarse_key, vectors, ncoarse,
                                        iters=iters)
    residuals = vectors - coarse_centers[coarse_ids]
    sub = residuals.reshape(n, m_subspaces, dsub)

    books, codes = [], []
    for j in range(m_subspaces):
        c, lbl = kmeans(keys[j], sub[:, j, :], ksub, iters=iters)
        books.append(c)
        codes.append(lbl)
    codebooks = jnp.stack(books)               # (M, ksub, dsub)
    centers_sub = coarse_centers.reshape(ncoarse, m_subspaces, dsub)
    # the ADC sweep is memory-bound at ~bytes-per-code: ksub <= 256 fits
    # uint8, quartering HBM traffic vs int32 codes (indices widen back to
    # int32 at use sites, e.g. the combined (coarse, code) kernel index)
    code_dtype = jnp.uint8 if ksub <= 256 else jnp.int32
    return PQIndex(
        codebooks=codebooks,
        codes=jnp.stack(codes, axis=1).astype(code_dtype),  # (n, M)
        coarse_centers=coarse_centers,
        coarse_ids=coarse_ids.astype(jnp.int32),
        cb_sq=jnp.sum(codebooks * codebooks, axis=-1),
        coarse_dot=jnp.einsum("cmd,mkd->cmk", centers_sub, codebooks),
    )


def compute_luts(index: PQIndex, queries: Array, *,
                 use_pallas: bool = False) -> Array:
    """(q, d) -> (q, ncoarse, M, ksub) squared-distance lookup tables.

    lut[qi, c, m, j] = || (q - coarse_c)_m - codebook[m, j] ||^2, i.e. the
    subspace distance to a row reconstructed as coarse_c + code j. Expanded
    as ||qres_m||^2 - 2 (q_m.cb_j - center_m.cb_j) + ||cb_j||^2 so the
    dominant q.cb cross term (one matmul over (q, d, ksub)) is ncoarse-free;
    only the cheap residual-norm term carries the coarse axis, and the
    center.cb / ||cb||^2 terms are precomputed at build time. With
    ``use_pallas`` that cross term runs as the fused ``ops.pq_lut_qdot``
    kernel (per-subspace codebook VMEM-resident, query blocks streamed).
    """
    q, d = queries.shape
    m, ksub, dsub = index.codebooks.shape
    qs = queries.reshape(q, m, dsub)
    q_dot = ops.pq_lut_qdot(qs, index.codebooks,
                            use_pallas=use_pallas)            # (q, M, ksub)
    qres = queries[:, None, :] - index.coarse_centers[None, :, :]  # (q, C, d)
    qres_sq = jnp.sum(qres.reshape(q, index.ncoarse, m, dsub) ** 2,
                      axis=-1)                                # (q, C, M)
    return (qres_sq[..., None]
            - 2.0 * (q_dot[:, None, :, :] - index.coarse_dot[None])
            + index.cb_sq[None, None])                        # (q, C, M, ksub)


@partial(jax.jit, static_argnames=("k", "use_pallas"))
def search(index: PQIndex, queries: Array, k: int, *,
           use_pallas: bool = False):
    """ADC scan: score every row from its coarse LUT; negative distance.

    ``use_pallas`` folds (coarse id, code) into one combined index and runs
    the one-hot-matmul ADC kernel over the flattened LUT.
    """
    n = index.size
    m, ksub = index.n_subspaces, index.ksub
    luts = compute_luts(index, queries,
                        use_pallas=use_pallas)           # (q, C, M, ksub)
    nq = luts.shape[0]

    if use_pallas:
        # combined (coarse, code) index; kernel sees ksub' = C * ksub
        ccodes = index.coarse_ids[:, None] * ksub + index.codes   # (n, M)
        big = luts.transpose(0, 2, 1, 3).reshape(nq, m, index.ncoarse * ksub)
        d2 = ops.pq_score_batch(ccodes, big)                      # (q, n)
        return jax.lax.top_k(-d2, min(k, n))

    # flat gather: pos[n, m] indexes lut.reshape(-1) at (coarse, m, code)
    pos = (index.coarse_ids[:, None] * (m * ksub)
           + jnp.arange(m)[None, :] * ksub + index.codes)         # (n, M)

    def one_query(lut):                                  # lut: (C, M, ksub)
        per_sub = lut.reshape(-1)[pos]                   # (n, M)
        d2 = jnp.sum(per_sub, axis=-1)
        return jax.lax.top_k(-d2, min(k, n))

    return jax.vmap(one_query)(luts)


def reconstruct(index: PQIndex, ids: Array) -> Array:
    """Decode rows back to d-dim vectors (for re-scoring fallbacks)."""
    codes = index.codes[ids]                     # (..., M)
    m = index.n_subspaces
    parts = [index.codebooks[j][codes[..., j]] for j in range(m)]
    residual = jnp.concatenate(parts, axis=-1)
    return index.coarse_centers[index.coarse_ids[ids]] + residual
