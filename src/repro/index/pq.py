"""Product Quantization index with ADC (asymmetric distance) LUT scoring.

TPU adaptation of the paper's third backend (ANNOY slot): PQ compresses each
vector into M int8 codes; queries build an (M, ksub) LUT of subspace distances
and score each corpus row with a gather-accumulate over its codes — a memory-
bound sweep at ~M bytes/row instead of 4d, i.e. a (4d/M)x compression of HBM
traffic. `repro/kernels/pq_lut.py` is the Pallas version of the scoring loop.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.clustering import kmeans, assign

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PQIndex:
    codebooks: Array  # (M, ksub, dsub)
    codes: Array      # (n, M) int32 in [0, ksub)

    def tree_flatten(self):
        return (self.codebooks, self.codes), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def size(self) -> int:
        return self.codes.shape[0]

    @property
    def n_subspaces(self) -> int:
        return self.codebooks.shape[0]

    @property
    def ksub(self) -> int:
        return self.codebooks.shape[1]


def build(vectors: Array, m_subspaces: int = 8, ksub: int = 256,
          rng: Array | None = None, iters: int = 15) -> PQIndex:
    vectors = jnp.asarray(vectors, jnp.float32)
    n, d = vectors.shape
    if d % m_subspaces:
        raise ValueError(f"d={d} must be divisible by M={m_subspaces}")
    dsub = d // m_subspaces
    ksub = min(ksub, n)
    if rng is None:
        rng = jax.random.PRNGKey(0)
    keys = jax.random.split(rng, m_subspaces)
    sub = vectors.reshape(n, m_subspaces, dsub)

    books, codes = [], []
    for j in range(m_subspaces):
        c, lbl = kmeans(keys[j], sub[:, j, :], ksub, iters=iters)
        books.append(c)
        codes.append(lbl)
    return PQIndex(
        codebooks=jnp.stack(books),            # (M, ksub, dsub)
        codes=jnp.stack(codes, axis=1).astype(jnp.int32),  # (n, M)
    )


def compute_luts(index: PQIndex, queries: Array) -> Array:
    """(q, d) -> (q, M, ksub) squared-distance lookup tables."""
    q, d = queries.shape
    m, ksub, dsub = index.codebooks.shape
    qs = queries.reshape(q, m, dsub)
    # (q, m, ksub): ||q_sub - c||^2
    diff = qs[:, :, None, :] - index.codebooks[None, :, :, :]
    return jnp.sum(diff * diff, axis=-1)


@partial(jax.jit, static_argnames=("k",))
def search(index: PQIndex, queries: Array, k: int):
    """ADC scan: score every row from the LUT; negative distance as score."""
    luts = compute_luts(index, queries)  # (q, M, ksub)

    def one_query(lut):
        # gather-accumulate: sum_m lut[m, code[n, m]]
        per_sub = jnp.take_along_axis(
            lut.T[None, :, :],                   # (1, ksub, M) -> broadcast
            index.codes[:, None, :],             # (n, 1, M)
            axis=1,
        )[:, 0, :]                               # (n, M)
        d2 = jnp.sum(per_sub, axis=-1)
        return jax.lax.top_k(-d2, min(k, index.size))

    return jax.vmap(one_query)(luts)


def reconstruct(index: PQIndex, ids: Array) -> Array:
    """Decode rows back to d-dim vectors (for re-scoring fallbacks)."""
    codes = index.codes[ids]                     # (..., M)
    m = index.n_subspaces
    parts = [index.codebooks[j][codes[..., j]] for j in range(m)]
    return jnp.concatenate(parts, axis=-1)
