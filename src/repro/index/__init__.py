"""Vector index backends (TPU-native: tiled matmul / IVF / PQ) + distributed search."""
from repro.index import flat, ivf, pq, slab, distributed
from repro.index.backend import SearchBackend

__all__ = ["flat", "ivf", "pq", "slab", "distributed", "SearchBackend"]
