"""SearchBackend — the uniform serving interface over all index backends.

``FCVIIndex`` holds one backend (flat / IVF / PQ) and queries it through this
protocol, so the query path is backend-agnostic and every backend exposes the
same ``use_pallas`` switch that routes its inner loop through
``repro.kernels.ops``. Backends are frozen pytree dataclasses whose ``search``
method delegates to the module-level jit'd function (the dataclass stays a
pure data container; jit caching keys on the static kwargs).

Contract
--------
``search(queries, k, *, use_pallas=False, **opts) -> (scores, ids)`` with
``queries``: (q, d) in the backend's (transformed) space, ``scores``: (q, k)
descending — higher is better, negative squared L2 for the exact backends —
and ``ids``: (q, k) int32 corpus row ids. Rows that cannot be filled (fewer
than ``k`` reachable candidates) carry ``-inf`` scores.

Storage dtype: the flat/IVF backends accept a build-time ``storage_dtype``
(threaded from ``FCVIConfig.storage_dtype``) and may hold the corpus at
reduced precision — bf16, or int8 codes with per-row fp32 dequant scales
(``repro.index.quant``). Scores are still fp32 — squared norms are fp32
computed from the stored (dequantized) values and matmuls accumulate fp32 —
so the contract above is unchanged; returned orderings are exact w.r.t. the
stored rows. ``search`` must stay traceable under ``jax.jit`` with static
``k`` and ``use_pallas``: the serving engine inlines it into its single
jitted per-batch step.

Serving layout: every backend (flat, IVF, PQ) also exposes ``slab()``
returning its ``repro.index.slab`` layout view — the object the device-mesh
serving layer shards (``slab.shard(mesh, rules)``) and the checkpoint layer
rematerialises at restore time. ``slab()`` is deliberately NOT part of this
protocol: it is a serving-layer concern, and the engine duck-types it.
"""
from __future__ import annotations

from typing import Protocol, Tuple, runtime_checkable

import jax

Array = jax.Array


@runtime_checkable
class SearchBackend(Protocol):
    """Anything FCVI can serve from: sized, searchable, kernel-dispatchable."""

    @property
    def size(self) -> int:
        """Number of indexed corpus rows."""
        ...

    def search(self, queries: Array, k: int, *, use_pallas: bool = False,
               **opts) -> Tuple[Array, Array]:
        """Top-k search; see module docstring for the contract."""
        ...
