"""IVF index — k-means coarse quantizer + padded inverted lists.

TPU adaptation of FAISS-IVF: inverted lists are materialised as a dense padded
matrix (nlist, max_list) of corpus row ids (pad = -1) so probing is a static
gather + block matmul, with no host-side variable-length loops. Sub-linear
cost: each query scores nprobe/nlist of the corpus.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clustering import kmeans, assign

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class IVFIndex:
    vectors: Array    # (n, d) corpus (transformed space)
    sq_norms: Array   # (n,)
    centroids: Array  # (nlist, d)
    lists: Array      # (nlist, max_list) int32 corpus ids, -1 pad
    list_sizes: Array  # (nlist,)

    def tree_flatten(self):
        return (self.vectors, self.sq_norms, self.centroids, self.lists, self.list_sizes), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def size(self) -> int:
        return self.vectors.shape[0]

    @property
    def nlist(self) -> int:
        return self.centroids.shape[0]

    @property
    def max_list(self) -> int:
        return self.lists.shape[1]


def build(vectors: Array, nlist: int, rng: Array | None = None,
          iters: int = 15, pad_to_multiple: int = 8) -> IVFIndex:
    """Train coarse quantizer and materialise padded lists (host-side)."""
    vectors = jnp.asarray(vectors, jnp.float32)
    if rng is None:
        rng = jax.random.PRNGKey(0)
    centroids, labels = kmeans(rng, vectors, nlist, iters=iters)
    labels_np = np.asarray(labels)
    n = vectors.shape[0]
    buckets = [np.nonzero(labels_np == j)[0] for j in range(nlist)]
    max_list = max(1, max(len(b) for b in buckets))
    if max_list % pad_to_multiple:
        max_list += pad_to_multiple - max_list % pad_to_multiple
    lists = np.full((nlist, max_list), -1, np.int32)
    sizes = np.zeros((nlist,), np.int32)
    for j, b in enumerate(buckets):
        lists[j, : len(b)] = b
        sizes[j] = len(b)
    return IVFIndex(
        vectors=vectors,
        sq_norms=jnp.sum(vectors * vectors, axis=-1),
        centroids=centroids,
        lists=jnp.asarray(lists),
        list_sizes=jnp.asarray(sizes),
    )


@partial(jax.jit, static_argnames=("k", "nprobe"))
def search(index: IVFIndex, queries: Array, k: int, nprobe: int = 8):
    """Probe the nprobe nearest lists per query; exact scoring inside lists.

    Returns (scores (q,k), indices (q,k)); scores are negative squared L2.
    """
    nprobe = min(nprobe, index.nlist)
    q2 = jnp.sum(queries * queries, axis=-1, keepdims=True)
    c2 = jnp.sum(index.centroids * index.centroids, axis=-1)
    cd = -(q2 - 2.0 * queries @ index.centroids.T + c2[None, :])  # (q, nlist)
    _, probe = jax.lax.top_k(cd, nprobe)  # (q, nprobe)

    def one_query(qv, q_sq, probes):
        cand = index.lists[probes].reshape(-1)            # (nprobe*max_list,)
        valid = cand >= 0
        safe = jnp.where(valid, cand, 0)
        rows = index.vectors[safe]                        # (c, d)
        row_sq = index.sq_norms[safe]
        s = -(q_sq - 2.0 * rows @ qv + row_sq)
        s = jnp.where(valid, s, -jnp.inf)
        kk = min(k, s.shape[0])
        v, p = jax.lax.top_k(s, kk)
        idx = safe[p]
        if kk < k:
            v = jnp.pad(v, (0, k - kk), constant_values=-jnp.inf)
            idx = jnp.pad(idx, (0, k - kk))
        return v, idx

    return jax.vmap(one_query)(queries, q2[:, 0], probe)


def add(index: IVFIndex, new_vectors: Array) -> IVFIndex:
    """Incremental insert (host-side rebuild of the padded lists).

    Centroids are kept fixed (standard IVF practice); lists regrow. The
    serving engine batches adds through a delta buffer and calls this on
    compaction, so the O(n) rebuild amortises.
    """
    new_vectors = jnp.asarray(new_vectors, jnp.float32)
    labels = assign(new_vectors, index.centroids)
    all_vecs = jnp.concatenate([index.vectors, new_vectors], axis=0)
    labels_np = np.asarray(labels)
    lists_np = np.asarray(index.lists)
    sizes_np = np.asarray(index.list_sizes).copy()
    nlist, max_list = lists_np.shape
    need = sizes_np.copy()
    for lbl in labels_np:
        need[lbl] += 1
    new_max = max(max_list, int(need.max()))
    if new_max % 8:
        new_max += 8 - new_max % 8
    out = np.full((nlist, new_max), -1, np.int32)
    out[:, :max_list] = lists_np
    base = index.vectors.shape[0]
    for i, lbl in enumerate(labels_np):
        out[lbl, sizes_np[lbl]] = base + i
        sizes_np[lbl] += 1
    return IVFIndex(
        vectors=all_vecs,
        sq_norms=jnp.sum(all_vecs * all_vecs, axis=-1),
        centroids=index.centroids,
        lists=jnp.asarray(out),
        list_sizes=jnp.asarray(sizes_np),
    )
