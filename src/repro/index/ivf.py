"""IVF index — k-means coarse quantizer + dual list layouts.

TPU adaptation of FAISS-IVF with two materialisations of the inverted lists:

  * ``lists`` (nlist, max_list) int32 corpus ids, -1 pad — the compact
    id layout used by ``add()``/compaction and for translating slab
    positions back to corpus rows.
  * ``grouped`` (nlist, max_list, d) dense slab of the corpus rows grouped
    by list (plus ``grouped_sq``/``valid``) — the SERVING layout, built once
    at ``build()`` time. Probing a list is then a contiguous slab DMA, which
    is exactly what the scalar-prefetch ``ivf_score`` Pallas kernel wants:
    the probe ids picked by the coarse quantizer index the BlockSpec
    index_map directly, so no per-row gather ever happens on the hot path.

Sub-linear cost: each query scores nprobe/nlist of the corpus.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clustering import kmeans, assign
from repro.index import quant
from repro.index.slab import build_grouped
from repro.kernels import ops

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class IVFIndex:
    vectors: Array     # (n, d) corpus (transformed space)
    sq_norms: Array    # (n,)
    centroids: Array   # (nlist, d)
    lists: Array       # (nlist, max_list) int32 corpus ids, -1 pad
    list_sizes: Array  # (nlist,)
    grouped: Array     # (nlist, max_list, d) corpus grouped by list (serving)
    grouped_sq: Array  # (nlist, max_list)
    valid: Array       # (nlist, max_list) float 0/1 (1 = real row)
    scales: Optional[Array] = None          # (n,) int8 per-row scales
    grouped_scales: Optional[Array] = None  # (nlist, max_list)

    def tree_flatten(self):
        return (self.vectors, self.sq_norms, self.centroids, self.lists,
                self.list_sizes, self.grouped, self.grouped_sq,
                self.valid, self.scales, self.grouped_scales), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def size(self) -> int:
        return self.vectors.shape[0]

    @property
    def nlist(self) -> int:
        return self.centroids.shape[0]

    @property
    def max_list(self) -> int:
        return self.lists.shape[1]

    def search(self, queries: Array, k: int, *, use_pallas: bool = False,
               **opts):
        """SearchBackend protocol entry point."""
        return search(self, queries, k, use_pallas=use_pallas, **opts)

    def search_rows(self, queries: Array, k: int, payload_v: Array,
                    payload_f: Array, *, grouped_pv=None, grouped_pf=None,
                    use_pallas: bool = False, **opts):
        """Gather-free SearchBackend entry point (rows, not just ids)."""
        return search_rows(self, queries, k, payload_v, payload_f,
                           grouped_pv, grouped_pf, use_pallas=use_pallas,
                           **opts)

    def slab(self):
        """The serving-layout view of this index (see ``repro.index.slab``):
        what the mesh-sharding and checkpoint layers consume."""
        from repro.index.slab import IVFSlab

        return IVFSlab(centroids=self.centroids, lists=self.lists,
                       grouped=self.grouped, grouped_sq=self.grouped_sq,
                       valid=self.valid, grouped_scales=self.grouped_scales)


# serving-layout materialisation lives with the layout type in index.slab
_grouped_slabs = build_grouped


def build(vectors: Array, nlist: int, rng: Array | None = None,
          iters: int = 15, pad_to_multiple: int = 8,
          storage_dtype=None) -> IVFIndex:
    """Train coarse quantizer and materialise both list layouts (host-side).

    ``storage_dtype`` (bfloat16 or int8) stores the corpus + serving slabs at
    reduced precision (2x / 4x effective HBM bandwidth on the probed scans);
    the quantizer is always trained in fp32 and squared norms are fp32
    computed FROM the stored (cast or dequantized) values, so slab scores
    stay exact for the stored rows. int8 additionally carries per-row scales
    in both layouts (``scales`` row-aligned, ``grouped_scales`` grouped)."""
    vectors = jnp.asarray(vectors, jnp.float32)
    if rng is None:
        rng = jax.random.PRNGKey(0)
    centroids, labels = kmeans(rng, vectors, nlist, iters=iters)
    labels_np = np.asarray(labels)
    buckets = [np.nonzero(labels_np == j)[0] for j in range(nlist)]
    max_list = max(1, max(len(b) for b in buckets))
    if max_list % pad_to_multiple:
        max_list += pad_to_multiple - max_list % pad_to_multiple
    lists = np.full((nlist, max_list), -1, np.int32)
    sizes = np.zeros((nlist,), np.int32)
    for j, b in enumerate(buckets):
        lists[j, : len(b)] = b
        sizes[j] = len(b)
    lists = jnp.asarray(lists)
    scales = grouped_scales = None
    if quant.is_quantized(storage_dtype):
        vectors, scales = quant.quantize_rows(vectors)
        sq_norms = quant.sq_norms_of(vectors, scales)
    else:
        if storage_dtype is not None:
            vectors = vectors.astype(storage_dtype)
        sq_norms = jnp.sum(vectors.astype(jnp.float32) ** 2, axis=-1)
    grouped, grouped_sq, valid = _grouped_slabs(vectors, sq_norms, lists)
    if scales is not None:
        grouped_scales = _group_scales(scales, lists)
    return IVFIndex(
        vectors=vectors,
        sq_norms=sq_norms,
        centroids=centroids,
        lists=lists,
        list_sizes=jnp.asarray(sizes),
        grouped=grouped,
        grouped_sq=grouped_sq,
        valid=valid,
        scales=scales,
        grouped_scales=grouped_scales,
    )


def _group_scales(scales: Array, lists: Array) -> Array:
    """Group per-row scales by list like ``build_grouped`` groups rows
    (invalid slots get scale 1.0 — they are masked by ``valid`` anyway,
    but a unit scale keeps any dequant of them finite)."""
    safe = jnp.maximum(lists, 0)
    return jnp.where(lists >= 0, scales[safe], 1.0)


@partial(jax.jit, static_argnames=("k", "nprobe", "use_pallas"))
def search(index: IVFIndex, queries: Array, k: int, nprobe: int = 8,
           *, use_pallas: bool = False):
    """Probe the nprobe nearest lists per query; exact scoring inside lists.

    Returns (scores (q,k), indices (q,k)); scores are negative squared L2.
    ``use_pallas`` runs the whole probe step on kernels: the coarse quantizer
    is a small ``ops.score_topk_padded`` call (centroid scoring is just a
    tiny flat search), and the probed lists are deduplicated across the query
    batch and scored probe-major by ``ops.ivf_score_topk_dedup`` so a slab
    shared by many queries is DMA'd from HBM once per batch.
    """
    nprobe = min(nprobe, index.nlist)
    q2 = jnp.sum(queries * queries, axis=-1, keepdims=True)
    c2 = jnp.sum(index.centroids * index.centroids, axis=-1)

    if use_pallas:
        _, probe = ops.score_topk_padded(index.centroids, c2, queries, nprobe)
        uniq, member = ops.dedup_probes(probe.astype(jnp.int32), index.nlist)
        vals, flat_ids = ops.ivf_score_topk_dedup(
            index.grouped, index.grouped_sq, index.valid, uniq, member,
            queries, k, scales=index.grouped_scales)
        cand = index.lists.reshape(-1)[flat_ids]        # -1 on padded slots
        vals = vals - q2                                # back to -||q - x||^2
        idx = jnp.where(jnp.isneginf(vals), 0, jnp.maximum(cand, 0))
        return vals, idx

    cd = -(q2 - 2.0 * queries @ index.centroids.T + c2[None, :])  # (q, nlist)
    _, probe = jax.lax.top_k(cd, nprobe)  # (q, nprobe)

    def one_query(qv, q_sq, probes):
        cand = index.lists[probes].reshape(-1)            # (nprobe*max_list,)
        valid = cand >= 0
        safe = jnp.where(valid, cand, 0)
        rows = index.vectors[safe]                        # (c, d)
        row_sq = index.sq_norms[safe]
        dot = rows.astype(qv.dtype) @ qv
        if index.scales is not None:
            dot = dot * index.scales[safe]
        s = -(q_sq - 2.0 * dot + row_sq)
        s = jnp.where(valid, s, -jnp.inf)
        kk = min(k, s.shape[0])
        v, p = jax.lax.top_k(s, kk)
        idx = safe[p]
        if kk < k:
            v = jnp.pad(v, (0, k - kk), constant_values=-jnp.inf)
            idx = jnp.pad(idx, (0, k - kk))
        return v, idx

    return jax.vmap(one_query)(queries, q2[:, 0], probe)


@partial(jax.jit, static_argnames=("k", "nprobe", "use_pallas"))
def search_rows(index: IVFIndex, queries: Array, k: int, payload_v: Array,
                payload_f: Array, grouped_pv=None, grouped_pf=None,
                nprobe: int = 8, *, use_pallas: bool = False):
    """Gather-free probed search: returns the winners' PAYLOAD ROWS too.

    payload_v (n, dv) / payload_f (n, m) are corpus-row-aligned (the re-rank
    originals); grouped_pv (nlist, max_list, dv) / grouped_pf are the same
    payloads in the grouped serving layout (built once by the engine via
    ``build_grouped_payload``), which the rows-returning dedup kernel streams
    through VMEM. Returns (scores (q,k), ids (q,k), rows_v (q,k,dv), rows_f
    (q,k,m)) with (scores, ids) identical to ``search``; unfilled (-inf)
    slots carry id 0 and corpus row 0's payload, matching the id-gather
    convention exactly (the phantom candidate competes in the final top-k).
    """
    nprobe = min(nprobe, index.nlist)
    if use_pallas:
        q2 = jnp.sum(queries * queries, axis=-1, keepdims=True)
        c2 = jnp.sum(index.centroids * index.centroids, axis=-1)
        _, probe = ops.score_topk_padded(index.centroids, c2, queries, nprobe)
        uniq, member = ops.dedup_probes(probe.astype(jnp.int32), index.nlist)
        vals, flat_ids, rows_v, rows_f = ops.ivf_score_topk_dedup_rows(
            index.grouped, index.grouped_sq, index.valid, uniq, member,
            queries, grouped_pv, grouped_pf, k,
            scales=index.grouped_scales)
        cand = index.lists.reshape(-1)[flat_ids]
        vals = vals - q2
        dead = jnp.isneginf(vals)
        idx = jnp.where(dead, 0, jnp.maximum(cand, 0))
        rows_v = jnp.where(dead[..., None], payload_v[0], rows_v)
        rows_f = jnp.where(dead[..., None], payload_f[0], rows_f)
        return vals, idx, rows_v, rows_f

    vals, idx = search(index, queries, k, nprobe=nprobe, use_pallas=False)
    return vals, idx, payload_v[idx], payload_f[idx]


# ---------------------------------------------------------------------------
# Filter-algebra candidate generation (mask / routed plans)
# ---------------------------------------------------------------------------

def grouped_mask(index: IVFIndex, elig: Array) -> Array:
    """Row eligibility (n,) bool -> the grouped-layout candidate mask
    (nlist, max_list) float 0/1 the dedup kernel streams (pad slots 0)."""
    safe = jnp.maximum(index.lists, 0)
    return (elig[safe] & (index.lists >= 0)).astype(jnp.float32)


def masked_candidates(index: IVFIndex, queries: Array, kk: int, elig: Array,
                      *, use_pallas: bool = False):
    """Exhaustive masked scan over ALL lists — the mask plan's candidate
    generator. Every eligible row competes (uniq = all list ids, full
    member matrix), ineligible rows score -inf in-kernel. Returns
    (cand (b, kk') corpus ids, valid (b, kk') bool) for ``filtered_refine``.
    """
    nlist = index.nlist
    kk = min(kk, nlist * index.max_list)
    uniq = jnp.arange(nlist, dtype=jnp.int32)
    member = jnp.ones((nlist, queries.shape[0]), jnp.float32)
    vals, flat_ids = ops.ivf_score_topk_dedup(
        index.grouped, index.grouped_sq, index.valid, uniq, member, queries,
        kk, scales=index.grouped_scales, mask=grouped_mask(index, elig),
        use_pallas=use_pallas)
    cand = index.lists.reshape(-1)[flat_ids]
    return jnp.maximum(cand, 0), ~jnp.isneginf(vals)


def routed_candidates(index: IVFIndex, queries: Array, kk: int, elig: Array,
                      uniq: Array, n_live, *, use_pallas: bool = False):
    """Masked scan restricted to a routed list set — the routed plan's
    candidate generator: only lists holding at least one eligible row are
    scanned (the rest of the corpus is pruned, never DMA'd).

    uniq: (slots,) int32 list ids, tail slots repeating a live id (the
    pow-2 padding from ``eligible_lists``); n_live: scalar count of live
    slots (data — the slot-bucket SIZE is the only static part, so routed
    predicates share traces per bucket). Returns (cand, valid) like
    ``masked_candidates``; exhaustive over the routed lists' eligible rows.
    """
    b = queries.shape[0]
    slots = uniq.shape[0]
    kk = min(kk, slots * index.max_list)
    member = ((jnp.arange(slots)[:, None] < n_live)
              .astype(jnp.float32) * jnp.ones((1, b), jnp.float32))
    vals, flat_ids = ops.ivf_score_topk_dedup(
        index.grouped, index.grouped_sq, index.valid, uniq, member, queries,
        kk, scales=index.grouped_scales, mask=grouped_mask(index, elig),
        use_pallas=use_pallas)
    cand = index.lists.reshape(-1)[flat_ids]
    return jnp.maximum(cand, 0), ~jnp.isneginf(vals)


def eligible_lists(lists_np: np.ndarray, elig_np: np.ndarray):
    """Host-side routing: which inverted lists hold >= 1 eligible row.

    Returns (uniq (slots,) int32, n_live int) with slots the next power of
    two >= n_live (tail repeats the first live id, masked by ``n_live`` in
    the traced member matrix), or None when no list qualifies (the caller
    short-circuits to an all-empty certified result).
    """
    lists_np = np.asarray(lists_np)
    elig_np = np.asarray(elig_np, bool)
    safe = np.maximum(lists_np, 0)
    has = (elig_np[safe] & (lists_np >= 0)).any(axis=1)
    ids = np.nonzero(has)[0].astype(np.int32)
    n_live = int(ids.shape[0])
    if n_live == 0:
        return None
    slots = 1 << max(0, int(n_live - 1).bit_length())
    uniq = np.full((slots,), ids[0], np.int32)
    uniq[:n_live] = ids
    return uniq, n_live


def build_grouped_payload(payload: Array, lists: Array) -> Array:
    """Materialise a corpus-row-aligned payload (n, x) in the grouped
    (nlist, max_list, x) serving layout (zeros on -1 padded slots), so the
    rows-returning dedup kernel can stream payload slabs with the same
    scalar-prefetch indirection as the corpus slabs."""
    safe = jnp.maximum(lists, 0)
    rows = payload[safe]                     # (nlist, max_list, x)
    return jnp.where((lists >= 0)[..., None], rows, 0.0)


def add(index: IVFIndex, new_vectors: Array) -> IVFIndex:
    """Incremental insert (host-side rebuild of the padded lists).

    Centroids are kept fixed (standard IVF practice); lists regrow and the
    serving slabs are re-materialised. The serving engine batches adds
    through a delta buffer and calls this on compaction, so the O(n) rebuild
    amortises.
    """
    new_vectors = jnp.asarray(new_vectors, jnp.float32)
    labels = assign(new_vectors, index.centroids)
    if index.scales is not None:
        new_codes, new_scales = quant.quantize_rows(new_vectors)
        all_vecs = jnp.concatenate([index.vectors, new_codes], axis=0)
        all_scales = jnp.concatenate([index.scales, new_scales], axis=0)
    else:
        all_vecs = jnp.concatenate(
            [index.vectors, new_vectors.astype(index.vectors.dtype)], axis=0)
        all_scales = None
    labels_np = np.asarray(labels)
    lists_np = np.asarray(index.lists)
    sizes_np = np.asarray(index.list_sizes).copy()
    nlist, max_list = lists_np.shape
    need = sizes_np.copy()
    for lbl in labels_np:
        need[lbl] += 1
    new_max = max(max_list, int(need.max()))
    if new_max % 8:
        new_max += 8 - new_max % 8
    out = np.full((nlist, new_max), -1, np.int32)
    out[:, :max_list] = lists_np
    base = index.vectors.shape[0]
    for i, lbl in enumerate(labels_np):
        out[lbl, sizes_np[lbl]] = base + i
        sizes_np[lbl] += 1
    lists = jnp.asarray(out)
    if all_scales is not None:
        sq_norms = quant.sq_norms_of(all_vecs, all_scales)
    else:
        sq_norms = jnp.sum(all_vecs.astype(jnp.float32) ** 2, axis=-1)
    grouped, grouped_sq, valid = _grouped_slabs(all_vecs, sq_norms, lists)
    grouped_scales = (None if all_scales is None
                      else _group_scales(all_scales, lists))
    return IVFIndex(
        vectors=all_vecs,
        sq_norms=sq_norms,
        centroids=index.centroids,
        lists=lists,
        list_sizes=jnp.asarray(sizes_np),
        grouped=grouped,
        grouped_sq=grouped_sq,
        valid=valid,
        scales=all_scales,
        grouped_scales=grouped_scales,
    )
