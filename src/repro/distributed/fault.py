"""Fault-tolerance coordinator logic: heartbeats, stragglers, elastic restart.

This container has one device, so the *policies* are implemented as pure,
unit-tested logic over simulated cluster state; `launch/train.py` wires them
to the real step loop (heartbeat = step completion, restart = checkpoint
restore onto the surviving mesh via `checkpoint.ckpt.restore(shardings=...)`).

Design targets (1000+-node posture):
* crash-only recovery — any host loss degrades to "load newest complete
  checkpoint on the largest feasible mesh" (ckpt.py guarantees atomicity);
* straggler mitigation — EWMA z-score on per-host step times; persistent
  stragglers are evicted exactly like failures (re-mesh without them), the
  standard TPU-pod practice since slow hosts gate every synchronous step.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence


@dataclasses.dataclass
class HostStats:
    host_id: int
    ewma: float = 0.0
    var: float = 0.0
    n: int = 0
    last_step: int = -1
    alive: bool = True


class HeartbeatTracker:
    """Tracks per-host step completion times; flags stragglers/failures."""

    def __init__(self, n_hosts: int, alpha: float = 0.2,
                 straggler_z: float = 3.0, straggler_patience: int = 3,
                 timeout_steps: int = 2):
        self.hosts = {h: HostStats(h) for h in range(n_hosts)}
        self.alpha = alpha
        self.straggler_z = straggler_z
        self.patience = straggler_patience
        self.timeout_steps = timeout_steps
        self._strag_count: dict = {h: 0 for h in range(n_hosts)}

    def record(self, host_id: int, step: int, step_time: float):
        st = self.hosts[host_id]
        if st.n == 0:
            st.ewma, st.var = step_time, 0.0
        else:
            d = step_time - st.ewma
            st.ewma += self.alpha * d
            st.var = (1 - self.alpha) * (st.var + self.alpha * d * d)
        st.n += 1
        st.last_step = step

    def _fleet_stats(self) -> tuple:
        ewmas = [s.ewma for s in self.hosts.values() if s.alive and s.n > 0]
        if not ewmas:
            return 0.0, 1.0
        mean = sum(ewmas) / len(ewmas)
        var = sum((e - mean) ** 2 for e in ewmas) / max(len(ewmas) - 1, 1)
        return mean, math.sqrt(max(var, 1e-12))

    def stragglers(self) -> list:
        """Hosts persistently z-sigma slower than the fleet."""
        mean, sd = self._fleet_stats()
        out = []
        for h, st in self.hosts.items():
            if not st.alive or st.n < self.patience:
                continue
            z = (st.ewma - mean) / max(sd, 1e-9)
            if z > self.straggler_z:
                self._strag_count[h] += 1
            else:
                self._strag_count[h] = 0
            if self._strag_count[h] >= self.patience:
                out.append(h)
        return out

    def failures(self, current_step: int) -> list:
        """Hosts silent for more than ``timeout_steps`` steps.

        A host that has NEVER recorded counts its silence from step 0 (not
        from the ``last_step = -1`` sentinel), so a fresh tracker at step 0
        reports no failures — nobody has had a chance to heartbeat yet.
        """
        return [h for h, st in self.hosts.items()
                if st.alive
                and current_step - max(st.last_step, 0) > self.timeout_steps]

    def mark_dead(self, host_ids: Sequence[int]):
        for h in host_ids:
            self.hosts[h].alive = False

    def mark_alive(self, host_ids: Sequence[int]):
        """Resurrect hosts (the self-healing cutover path): alive again with
        a clean straggler record, EWMA history retained."""
        for h in host_ids:
            self.hosts[h].alive = True
            self._strag_count[h] = 0

    def alive_hosts(self) -> list:
        return [h for h, st in self.hosts.items() if st.alive]


# ---------------------------------------------------------------------------
# Elastic restart planning
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RestartPlan:
    mesh_shape: tuple          # new (data, model) or (pod, data, model)
    n_devices: int
    dropped_hosts: tuple
    batch_scale: float         # new_global_batch / old_global_batch


def plan_restart(n_alive_devices: int, model_parallel: int,
                 old_mesh_shape: tuple, dropped_hosts: Sequence[int],
                 pods: int = 1) -> Optional[RestartPlan]:
    """Largest feasible (data, model) mesh keeping TP size fixed.

    TP ('model') must stay intact (param shardings depend on it); the data
    axis shrinks to the largest multiple that fits the survivors. Returns
    None when fewer than one TP group survives.
    """
    per_pod = n_alive_devices // max(pods, 1)
    data = per_pod // model_parallel
    if data < 1:
        return None
    old_data = old_mesh_shape[-2] if len(old_mesh_shape) >= 2 else 1
    shape = (pods, data, model_parallel) if pods > 1 else (data, model_parallel)
    return RestartPlan(
        mesh_shape=shape,
        n_devices=pods * data * model_parallel,
        dropped_hosts=tuple(sorted(dropped_hosts)),
        batch_scale=data / max(old_data, 1),
    )


def reassign_microbatches(n_micro: int, alive_hosts: Sequence[int]) -> dict:
    """Deterministic microbatch -> host map after an eviction (round-robin).

    Keeps every microbatch owned (no data loss) while the evicted host's
    share is spread evenly — the straggler-mitigation data plan.
    """
    alive = sorted(alive_hosts)
    return {mb: alive[mb % len(alive)] for mb in range(n_micro)}
