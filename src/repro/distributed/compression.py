"""Gradient compression for the scarce cross-pod links (DESIGN.md §5).

int8 block-quantization with stochastic rounding: unbiased (E[deq] = x), so
SGD/Adam convergence is preserved in expectation; per-block scales bound the
worst-case error to scale/2. The intended deployment is a two-stage gradient
sync on the multi-pod mesh: full-precision reduce-scatter WITHIN a pod (fat
ICI), int8 all-reduce ACROSS pods (thin DCI) — `cross_pod_grad_sync` wires
that as a shard_map; CI validates unbiasedness, error bounds and the
end-to-end sync on fake devices.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

Array = jax.Array

BLOCK = 256


def _pad_to_block(x: Array):
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), pad


def quantize_int8(x: Array, rng: Array):
    """Block-wise int8 quantization with stochastic rounding.

    Returns (codes int8 (nblocks, BLOCK), scales f32 (nblocks,), pad).
    Unbiased: E[dequantize(quantize(x))] == x.
    """
    blocks, pad = _pad_to_block(x.astype(jnp.float32))
    scales = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    safe = jnp.maximum(scales, 1e-12)
    scaled = blocks / safe[:, None]
    noise = jax.random.uniform(rng, scaled.shape)
    codes = jnp.clip(jnp.floor(scaled + noise), -127, 127).astype(jnp.int8)
    return codes, scales, pad


def dequantize_int8(codes: Array, scales: Array, pad: int, shape, dtype):
    flat = (codes.astype(jnp.float32) * scales[:, None]).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape).astype(dtype)


def compress_ratio(x: Array) -> float:
    """Bytes(int8 codes + scales) / bytes(f32)."""
    nblocks = -(-x.size // BLOCK)
    return (nblocks * BLOCK + nblocks * 4) / (x.size * 4)


def cross_pod_grad_sync(mesh: Mesh, pod_axis: str = "pod"):
    """Two-stage gradient sync: f32 psum within-pod axes, int8 across pods.

    Returns fn(grads_leaf (…), rng) -> synced leaf. Built with shard_map so
    the cross-pod stage quantizes exactly once per step. For meshes without
    a 'pod' axis this degrades to a plain psum.
    """
    axes = mesh.axis_names
    inner = tuple(a for a in axes if a != pod_axis)
    has_pod = pod_axis in axes

    def sync(g, rng):
        def local(gl, key):
            for ax in inner:
                gl = jax.lax.psum(gl, ax)
            if not has_pod:
                return gl
            # int8 the cross-pod hop: quantize, psum codes as f32 partial
            # sums of dequantized values (wire format int8; the reference
            # semantics here use dequant-then-psum, which matches an
            # all-to-all + local dequant-accumulate implementation)
            codes, scales, pad = quantize_int8(gl, key)
            deq = dequantize_int8(codes, scales, pad, gl.shape, gl.dtype)
            return jax.lax.psum(deq, pod_axis)

        return shard_map(
            local, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
            check_vma=False)(g, rng)

    return sync
