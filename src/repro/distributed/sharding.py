"""Logical-axis sharding rules + activation constraint helpers.

Model code annotates activations with LOGICAL axis names ("batch", "seq",
"heads", "ff", "vocab", ...) via ``shard_act``; a process-wide ``AxisRules``
context resolves them to mesh axes (or to no-ops when no mesh is active, so
the same model code runs on 1 CPU device in tests).

Parameter shardings are derived from leaf names by ``param_spec`` so
``jax.jit(in_shardings=...)`` gets a PartitionSpec tree that matches
``init_params`` exactly.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# logical name -> mesh axis (or tuple of axes, or None=replicate)
DEFAULT_RULES = {
    "batch": ("pod", "data"),   # DP over pod x data
    "seq": None,                # replicated by default (TP keeps seq whole)
    "kv_seq": "model",          # decode KV caches: sequence-sharded
    "heads": "model",
    "kv_heads": None,           # few KV heads: replicate (see param_spec)
    "embed": None,
    "head_dim": None,
    "ff": "model",
    "moe_ff": None,             # per-expert ff: unsharded under EP (experts
                                # take 'model'); granite overrides (E % 16 != 0)
    "vocab": "model",
    "experts": "model",         # EP
    "rnn": "model",
    "corpus": ("pod", "data"),  # FCVI corpus rows (flat slabs, rescore rows)
    "ivf_lists": ("pod", "data"),  # FCVI IVF inverted lists (grouped slabs)
    "none": None,
}


class AxisRules:
    def __init__(self, mesh: Optional[Mesh], rules: Optional[dict] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)
        if mesh is not None:
            # drop axes the mesh does not have (e.g. "pod" on single-pod)
            have = set(mesh.axis_names)

            def fix(v):
                if v is None:
                    return None
                if isinstance(v, tuple):
                    kept = tuple(a for a in v if a in have)
                    return kept if kept else None
                return v if v in have else None

            self.rules = {k: fix(v) for k, v in self.rules.items()}

    def spec(self, *names: Optional[str]) -> P:
        return P(*[self.rules.get(n or "none") for n in names])


def current_rules() -> Optional[AxisRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[AxisRules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def shard_act(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Constrain activation x to the logical spec; no-op without a mesh."""
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    spec = r.spec(*names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter shardings by leaf path name
# ---------------------------------------------------------------------------

def _leaf_logical(path: str, ndim: int, scanned: bool) -> tuple:
    """Map a parameter leaf (by its path string) to logical axis names.

    ``scanned`` leaves carry a leading stacked-periods dim (replicated).
    """
    name = path.split("/")[-1]
    base: tuple
    if name in ("embedding",):
        base = ("vocab", "embed")
    elif name in ("wq",):
        base = ("embed", "heads", "head_dim")
    elif name in ("wk", "wv"):
        base = ("embed", "kv_heads", "head_dim")
    elif name in ("wo",):
        base = ("heads", "head_dim", "embed")
    elif name in ("w_in", "w_gate"):
        base = ("embed", "ff")
    elif name in ("w_out",):
        base = ("ff", "embed")
    elif name in ("we_in", "we_gate"):          # MoE expert weights
        base = ("experts", "embed", "moe_ff")
    elif name in ("we_out",):
        base = ("experts", "moe_ff", "embed")
    elif name in ("w_router",):
        base = ("embed", "experts")
    elif name in ("lm_head",):
        base = ("embed", "vocab")
    elif name in ("w_rnn_in", "w_rnn_gate"):    # RG-LRU input projections
        base = ("embed", "rnn")
    elif name in ("w_rnn_out",):
        base = ("rnn", "embed")
    elif name in ("w_gate_a", "w_gate_x"):      # RG-LRU recurrence gates
        base = ("none", "rnn")                  # square (d_rnn, d_rnn): shard
                                                # output dim only
    elif name in ("conv_w",):                   # temporal conv (width, rnn)
        base = ("none", "rnn")
    elif name in ("wqkv_lstm",):                # xLSTM fused projections
        base = ("embed", "none", "heads", "head_dim")
    elif name in ("w_lstm_out",):
        base = ("heads", "head_dim", "embed")
    elif name in ("w_gates",):                  # xLSTM scalar gates
        base = ("embed", "none", "heads")
    else:
        base = tuple("none" for _ in range(ndim - (1 if scanned else 0)))
    if scanned:
        base = ("none",) + base
    # pad/trim against actual rank (bias vectors etc.)
    if len(base) != ndim:
        base = tuple("none" for _ in range(ndim))
    return base


def param_spec_tree(params: Any, rules: AxisRules) -> Any:
    """PartitionSpec tree for a param pytree (path-name driven)."""

    def visit(path, leaf):
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        scanned = "scan" in pstr
        names = _leaf_logical(pstr, leaf.ndim, scanned)
        return rules.spec(*names)

    return jax.tree_util.tree_map_with_path(visit, params)


def named_sharding_tree(params: Any, rules: AxisRules) -> Any:
    specs = param_spec_tree(params, rules)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(rules.mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
