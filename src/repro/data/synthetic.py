"""Synthetic vector+filter corpora structurally matched to the paper's data.

The paper's datasets (SIFT1M + synthetic filters, Amazon product, ArXiv,
Wikipedia) are unavailable offline; these generators reproduce their
*structure* (DESIGN.md §6.3): mixture-of-Gaussians vectors, filters that are
a concatenation of Zipf-categorical one-hot groups and uniform numeric
attributes (2-5 attributes, paper §6.1.1), plus the three distribution-shift
protocols of Table 2.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class CorpusSpec:
    n: int = 50_000
    d: int = 128
    n_vec_clusters: int = 32
    n_categories: int = 8           # Zipf categorical attribute
    n_numeric: int = 3              # uniform numeric attributes
    zipf_a: float = 1.5
    noise: float = 0.35
    corr: float = 0.6               # filter<->vector-cluster correlation
    seed: int = 0

    @property
    def m(self) -> int:
        return self.n_categories + self.n_numeric


@dataclasses.dataclass
class Corpus:
    vectors: np.ndarray             # (n, d) f32
    filters: np.ndarray             # (n, m) f32
    vec_labels: np.ndarray          # (n,) vector cluster ids
    cat_labels: np.ndarray          # (n,) categorical attribute values
    spec: CorpusSpec


def make_corpus(spec: CorpusSpec) -> Corpus:
    rng = np.random.default_rng(spec.seed)
    centers = rng.normal(size=(spec.n_vec_clusters, spec.d)).astype(np.float32)
    labels = rng.integers(0, spec.n_vec_clusters, spec.n)
    vectors = (centers[labels]
               + spec.noise * rng.normal(size=(spec.n, spec.d))).astype(np.float32)

    # categorical attribute: Zipf-distributed, correlated with vector cluster
    zipf_p = 1.0 / np.arange(1, spec.n_categories + 1) ** spec.zipf_a
    zipf_p /= zipf_p.sum()
    random_cat = rng.choice(spec.n_categories, size=spec.n, p=zipf_p)
    correlated_cat = labels % spec.n_categories
    use_corr = rng.random(spec.n) < spec.corr
    cat = np.where(use_corr, correlated_cat, random_cat)
    onehot = np.zeros((spec.n, spec.n_categories), np.float32)
    onehot[np.arange(spec.n), cat] = 1.0

    # numeric attributes: uniform, one correlated with cluster id
    numeric = rng.uniform(0.0, 1.0, size=(spec.n, spec.n_numeric)).astype(np.float32)
    if spec.n_numeric > 0:
        numeric[:, 0] = (labels / spec.n_vec_clusters
                         + 0.1 * rng.normal(size=spec.n)).astype(np.float32)

    filters = np.concatenate([onehot, numeric], axis=1)
    return Corpus(vectors=vectors, filters=filters, vec_labels=labels,
                  cat_labels=cat, spec=spec)


def sample_queries(corpus: Corpus, n_queries: int, seed: int = 1,
                   in_distribution: bool = True):
    """Queries near corpus clusters with filter targets drawn from the data."""
    rng = np.random.default_rng(seed)
    spec = corpus.spec
    idx = rng.integers(0, spec.n, n_queries)
    q = (corpus.vectors[idx]
         + 0.5 * spec.noise * rng.normal(size=(n_queries, spec.d))).astype(np.float32)
    if in_distribution:
        fq = corpus.filters[rng.integers(0, spec.n, n_queries)].copy()
    else:
        fq = rng.normal(size=(n_queries, spec.m)).astype(np.float32)
    return q, fq.astype(np.float32)


# ---------------------------------------------------------------------------
# Distribution shifts (Table 2 protocols)
# ---------------------------------------------------------------------------

def shift_filter_distribution(corpus: Corpus, seed: int = 7) -> Corpus:
    """Low -> high selectivity: concentrate categories on the rare tail and
    stretch the numeric attribute (the paper's 'filter distribution change')."""
    rng = np.random.default_rng(seed)
    spec = corpus.spec
    new = Corpus(vectors=corpus.vectors.copy(), filters=corpus.filters.copy(),
                 vec_labels=corpus.vec_labels.copy(),
                 cat_labels=corpus.cat_labels.copy(), spec=spec)
    # remap: most-frequent category -> rarest (inverts selectivity)
    remap = np.arange(spec.n_categories)[::-1]
    cat = remap[corpus.cat_labels]
    onehot = np.zeros((spec.n, spec.n_categories), np.float32)
    onehot[np.arange(spec.n), cat] = 1.0
    new.filters[:, : spec.n_categories] = onehot
    # compress numeric mass into the upper half (selectivity shift while
    # staying in-support — the paper's low->high selectivity protocol)
    new.filters[:, spec.n_categories:] = (
        0.5 + 0.5 * corpus.filters[:, spec.n_categories:])
    new.cat_labels = cat
    return new


def shift_vector_distribution(corpus: Corpus, frac_new: float = 0.3,
                              seed: int = 8) -> Corpus:
    """Inject novel vector clusters (the paper's 'vector distribution change')."""
    rng = np.random.default_rng(seed)
    spec = corpus.spec
    n_new = int(spec.n * frac_new)
    k_new = max(4, spec.n_vec_clusters // 4)
    centers = 2.5 * rng.normal(size=(k_new, spec.d)).astype(np.float32)
    labels = rng.integers(0, k_new, n_new)
    vec_new = (centers[labels]
               + spec.noise * rng.normal(size=(n_new, spec.d))).astype(np.float32)
    cat_new = rng.integers(0, spec.n_categories, n_new)
    onehot = np.zeros((n_new, spec.n_categories), np.float32)
    onehot[np.arange(n_new), cat_new] = 1.0
    num_new = rng.uniform(0, 1, size=(n_new, spec.n_numeric)).astype(np.float32)
    filt_new = np.concatenate([onehot, num_new], axis=1)

    keep = spec.n - n_new
    return Corpus(
        vectors=np.concatenate([corpus.vectors[:keep], vec_new]),
        filters=np.concatenate([corpus.filters[:keep], filt_new]),
        vec_labels=np.concatenate(
            [corpus.vec_labels[:keep], labels + spec.n_vec_clusters]),
        cat_labels=np.concatenate([corpus.cat_labels[:keep], cat_new]),
        spec=spec,
    )


def shifted_query_pattern(corpus: Corpus, n_queries: int, seed: int = 9):
    """Out-of-pattern queries: off-cluster vectors + rare-category filters."""
    rng = np.random.default_rng(seed)
    spec = corpus.spec
    q = rng.normal(size=(n_queries, spec.d)).astype(np.float32) * 1.5
    rare = spec.n_categories - 1 - rng.integers(0, max(spec.n_categories // 3, 1),
                                                n_queries)
    onehot = np.zeros((n_queries, spec.n_categories), np.float32)
    onehot[np.arange(n_queries), rare] = 1.0
    num = rng.uniform(0.8, 1.0, size=(n_queries, spec.n_numeric)).astype(np.float32)
    return q, np.concatenate([onehot, num], axis=1).astype(np.float32)
