"""Deterministic synthetic LM token pipeline.

A first-order Markov chain over the vocabulary gives the models real,
learnable structure (loss decreases measurably within a few hundred steps) —
unlike uniform-random tokens — while remaining fully offline and reproducible.
Per-host sharded loading: each data-parallel host draws only its slice of the
global batch from a host-indexed PRNG stream (emulated single-host here).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenSpec:
    vocab_size: int
    batch: int                 # per-host batch
    seq_len: int
    seed: int = 0
    branching: int = 16        # successors per token (lower = easier)
    host_id: int = 0
    n_hosts: int = 1


class MarkovTokens:
    """Infinite iterator of {"tokens": (batch, seq_len) int32} batches."""

    def __init__(self, spec: TokenSpec):
        self.spec = spec
        rng = np.random.default_rng(spec.seed)
        v, b = spec.vocab_size, spec.branching
        self.succ = rng.integers(0, v, size=(v, b)).astype(np.int32)
        probs = rng.dirichlet(np.ones(b) * 0.5, size=v).astype(np.float32)
        self.cum = np.cumsum(probs, axis=1)
        self._step = 0

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        s = self.spec
        rng = np.random.default_rng(
            (s.seed, s.host_id, self._step))
        self._step += 1
        b, L, v = s.batch, s.seq_len, s.vocab_size
        out = np.empty((b, L), np.int32)
        out[:, 0] = rng.integers(0, v, b)
        u = rng.random((b, L))
        for t in range(1, L):
            prev = out[:, t - 1]
            choice = (u[:, t][:, None] > self.cum[prev]).sum(axis=1)
            out[:, t] = self.succ[prev, np.minimum(choice, s.branching - 1)]
        return {"tokens": out}


def global_batch_iterator(spec: TokenSpec, extras: Optional[dict] = None):
    """Adds stub frontend inputs (frames/patches) when extras request them."""
    stream = MarkovTokens(spec)
    rng = np.random.default_rng(spec.seed + 101)
    for batch in stream:
        if extras:
            for key, shape in extras.items():
                batch[key] = rng.normal(size=(spec.batch, *shape)).astype(np.float32)
        yield batch
