"""Deterministic fault injection for degraded-serving tests and benchmarks.

Three families of faults, all reproducible (no randomness, no timing races):

  * dispatch faults — ``FaultInjector`` attaches to ``FCVIEngine`` (via
    ``engine.fault_injector``) and (a) raises ``TransientShardError`` for the
    next N batches, exercising the bounded-retry/backoff envelope, and
    (b) feeds SYNTHETIC per-shard step times into the health layer's
    heartbeat (slow shards -> straggler eviction). Synthetic times are the
    only way to drive the straggler detector on a forced host mesh: all
    "shards" share the same cores, so real per-shard timing is neither
    observable in-process nor deterministic.

  * shard loss — not injected here (just ``engine.health.mark_dead``); what
    this module provides is the GROUND TRUTH to check degraded results
    against: ``surviving_reference(engine)`` builds a meshless engine over
    the same corpus with every dead shard's slab rows invalidated in place
    (flat: ``sq_norms=+inf``; IVF: dead lists emptied + grouped slabs
    rebuilt). Invalidating instead of deleting keeps ``index.size`` — and
    therefore the k' over-retrieval and escalation thresholds — IDENTICAL to
    the degraded engine's, so full end-to-end ``engine.search`` results must
    be bit-identical (the tentpole acceptance criterion).

  * state corruption — ``corrupt_checkpoint`` tears/flips/deletes pieces of
    an on-disk checkpoint step to exercise ``ckpt``'s integrity verification
    and newest-intact-step fallback.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.serve.health import TransientShardError


@dataclasses.dataclass
class FaultInjector:
    """Deterministic per-batch fault source for ``FCVIEngine``.

    ``transient_failures``: the next N dispatched batches raise
    ``TransientShardError`` from ``before_batch`` (the engine retries with
    backoff; N <= ``cfg.max_retries`` eventually succeeds, larger N
    propagates). ``slow_shards``: shard -> slowdown factor applied to the
    synthetic heartbeat times (persistently slow shards get straggler-
    evicted by the health layer). ``base_step_time``: the healthy synthetic
    per-shard step time in seconds.
    """

    transient_failures: int = 0
    slow_shards: Dict[int, float] = dataclasses.field(default_factory=dict)
    base_step_time: float = 0.01
    injected: int = 0

    def before_batch(self):
        if self.transient_failures > 0:
            self.transient_failures -= 1
            self.injected += 1
            raise TransientShardError(
                f"injected transient dispatch failure "
                f"({self.transient_failures} left)")

    def shard_times(self, n_shards: int, elapsed: float) -> List[float]:
        return [self.base_step_time * self.slow_shards.get(s, 1.0)
                for s in range(n_shards)]


# ---------------------------------------------------------------------------
# Ground truth for shard loss: the surviving-rows reference engine
# ---------------------------------------------------------------------------

def surviving_row_mask(engine) -> np.ndarray:
    """(index.size,) bool — True for rows whose owning shard is alive.

    Ownership is the SLAB placement (``ShardedServing.slab_row_owner``):
    a shard's death removes exactly its slab block from candidate
    generation; the re-rank originals and the delta buffer are durable.
    """
    owner = engine._sharded.slab_row_owner()
    return engine.health.alive_mask()[owner]


def surviving_reference(engine):
    """A meshless engine whose candidate space is exactly the survivors.

    Same transform, same re-rank originals, same ``index.size`` (dead rows
    are invalidated in place, not removed — keeping k' and escalation
    thresholds identical), same configs, same pending delta rows. Degraded
    ``engine.search`` results must equal this engine's results bit-for-bit.
    """
    from repro.serve.engine import FCVIEngine

    idx = engine.index
    mask = surviving_row_mask(engine)
    b = idx.backend
    if idx.config.backend == "flat":
        # +inf squared norm -> the scoring expansion q.x - 0.5*||x||^2 is
        # -inf, so dead rows can never enter the candidate set
        sq = jnp.where(jnp.asarray(mask), b.sq_norms, jnp.inf)
        backend = dataclasses.replace(b, sq_norms=sq)
    elif idx.config.backend == "ivf":
        from repro.index.slab import build_grouped

        l2s = np.asarray(engine._sharded.slab.list_to_shard)
        dead_list = ~engine.health.alive_mask()[l2s]
        lists = np.asarray(b.lists).copy()
        sizes = np.asarray(b.list_sizes).copy()
        lists[dead_list] = -1          # empty the dead shards' lists;
        sizes[dead_list] = 0           # centroids stay (probe selection
        lists_j = jnp.asarray(lists)   # must match the degraded step's)
        grouped, grouped_sq, valid = build_grouped(
            b.vectors, b.sq_norms, lists_j)
        backend = dataclasses.replace(
            b, lists=lists_j, list_sizes=jnp.asarray(sizes),
            grouped=grouped, grouped_sq=grouped_sq, valid=valid)
    else:
        raise NotImplementedError(
            f"surviving_reference: backend {idx.config.backend!r}")
    ref_idx = dataclasses.replace(idx, backend=backend)
    ref = FCVIEngine(ref_idx, dataclasses.replace(engine.cfg))
    ref._delta_v = [np.array(v, copy=True) for v in engine._delta_v]
    ref._delta_f = [np.array(f, copy=True) for f in engine._delta_f]
    return ref


# ---------------------------------------------------------------------------
# Checkpoint corruption
# ---------------------------------------------------------------------------

def corrupt_checkpoint(ckpt_dir: str, step: int, mode: str = "truncate"):
    """Deterministically damage one on-disk checkpoint step.

    ``mode``: 'truncate' cuts arrays.npz in half (a torn write);
    'flip' XORs one byte in the middle of arrays.npz (silent bit rot —
    caught by the manifest checksums); 'erase_manifest' makes
    manifest.json unparseable.
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    npz = os.path.join(d, "arrays.npz")
    if mode == "truncate":
        size = os.path.getsize(npz)
        with open(npz, "r+b") as f:
            f.truncate(size // 2)
    elif mode == "flip":
        size = os.path.getsize(npz)
        with open(npz, "r+b") as f:
            f.seek(size // 2)
            byte = f.read(1)
            f.seek(size // 2)
            f.write(bytes([byte[0] ^ 0xFF]))
    elif mode == "erase_manifest":
        with open(os.path.join(d, "manifest.json"), "w") as f:
            f.write("{ torn json")
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")


# ---------------------------------------------------------------------------
# Poisoned inputs (for the input-hardening boundary tests)
# ---------------------------------------------------------------------------

def poisoned_inputs(d: int, m: int) -> list:
    """(name, queries, filters) triples that ``engine.search`` must reject
    with a ``ValueError`` instead of producing garbage top-k."""
    q = np.zeros((2, d), np.float32)
    f = np.zeros((2, m), np.float32)
    qn = q.copy(); qn[0, 0] = np.nan
    qi = q.copy(); qi[1, -1] = np.inf
    fn = f.copy(); fn[0, 0] = np.nan
    fhuge = f.copy(); fhuge[0, 0] = 1e30
    return [
        ("nan_query", qn, f),
        ("inf_query", qi, f),
        ("nan_filter", q, fn),
        ("out_of_support_filter", q, fhuge),
        ("dim_mismatch_query", np.zeros((2, d + 1), np.float32), f),
        ("dim_mismatch_filter", q, np.zeros((2, m + 1), np.float32)),
        ("batch_mismatch", q, np.zeros((3, m), np.float32)),
        ("empty_batch", np.zeros((0, d), np.float32),
         np.zeros((0, m), np.float32)),
        ("not_2d", np.zeros((d,), np.float32), f),
    ]
