"""Batched FCVI serving engine (§4.3 optimizations, production shape).

Implements the paper's serving-side optimizations on top of FCVIIndex:
  * request batching (group queries, amortise index traversal),
  * filter-aware result cache (common filter combinations hit the cache),
  * adaptive k' with two-stage escalation (early-termination dual: retrieve
    with a small k', escalate only queries whose top-k margin is ambiguous),
  * delta buffer for inserts + background compaction (updates without
    rebuilding the main index per insert),
  * multi-probe execution for range/disjunctive predicates.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fcvi
from repro.core.baselines import BoxPredicate
from repro.core.fcvi import FCVIConfig, FCVIIndex
from repro.index import flat as flat_mod


@dataclasses.dataclass
class EngineConfig:
    k: int = 10
    batch_size: int = 64
    cache_entries: int = 4096
    cache_round: float = 0.05      # filter-key quantization for cache hits
    escalate_margin: float = 0.02  # top-k score margin triggering stage 2
    kprime_escalation: int = 4     # stage-2 k' multiplier
    compact_threshold: int = 2048  # delta rows triggering compaction
    multi_probe_r: int = 4


@dataclasses.dataclass
class EngineStats:
    queries: int = 0
    cache_hits: int = 0
    escalations: int = 0
    inserts: int = 0
    compactions: int = 0
    total_time_s: float = 0.0

    @property
    def qps(self) -> float:
        return self.queries / self.total_time_s if self.total_time_s else 0.0


class FCVIEngine:
    def __init__(self, index: FCVIIndex, config: EngineConfig = EngineConfig()):
        self.index = index
        self.cfg = config
        self.stats = EngineStats()
        self._cache: "collections.OrderedDict" = collections.OrderedDict()
        self._delta_v: list = []
        self._delta_f: list = []

    # -- cache ------------------------------------------------------------
    def _cache_key(self, q: np.ndarray, f: np.ndarray) -> bytes:
        r = self.cfg.cache_round
        qq = np.round(q / r).astype(np.int32)
        ff = np.round(f / r).astype(np.int32)
        return qq.tobytes() + b"#" + ff.tobytes()

    def _cache_get(self, key: bytes):
        if key in self._cache:
            self._cache.move_to_end(key)
            return self._cache[key]
        return None

    def _cache_put(self, key: bytes, value):
        self._cache[key] = value
        self._cache.move_to_end(key)
        while len(self._cache) > self.cfg.cache_entries:
            self._cache.popitem(last=False)

    # -- search -----------------------------------------------------------
    def search(self, queries: np.ndarray, filters: np.ndarray):
        """queries: (n, d); filters: (n, m). Returns (scores, ids) (n, k)."""
        t0 = time.perf_counter()
        n = queries.shape[0]
        k = self.cfg.k
        out_scores = np.zeros((n, k), np.float32)
        out_ids = np.zeros((n, k), np.int64)

        todo = []
        for i in range(n):
            key = self._cache_key(queries[i], filters[i])
            hit = self._cache_get(key)
            if hit is not None:
                out_scores[i], out_ids[i] = hit
                self.stats.cache_hits += 1
            else:
                todo.append(i)

        bs = self.cfg.batch_size
        for s in range(0, len(todo), bs):
            idxs = todo[s:s + bs]
            pad = bs - len(idxs)
            q = np.concatenate([queries[idxs],
                                np.zeros((pad, queries.shape[1]), np.float32)])
            f = np.concatenate([filters[idxs],
                                np.zeros((pad, filters.shape[1]), np.float32)])
            scores, ids = self._staged_query(jnp.asarray(q), jnp.asarray(f), k)
            scores, ids = np.asarray(scores), np.asarray(ids)
            for j, i in enumerate(idxs):
                sc, di = self._merge_delta(queries[i], filters[i], scores[j], ids[j], k)
                out_scores[i], out_ids[i] = sc, di
                self._cache_put(self._cache_key(queries[i], filters[i]), (sc, di))

        self.stats.queries += n
        self.stats.total_time_s += time.perf_counter() - t0
        return out_scores, out_ids

    def _staged_query(self, q, f, k):
        scores, ids = fcvi.query(self.index, q, f, k)
        margin = scores[:, 0] - scores[:, -1]
        need = np.asarray(margin < self.cfg.escalate_margin)
        if need.any():
            self.stats.escalations += int(need.sum())
            from repro.core import theory
            cfg = self.index.config
            kp2 = theory.k_prime(k, cfg.lam, cfg.resolved_alpha(),
                                 self.index.size,
                                 cfg.c * self.cfg.kprime_escalation)
            s2, i2 = fcvi.query(self.index, q, f, k, k_prime=kp2)
            sel = jnp.asarray(need)[:, None]
            scores = jnp.where(sel, s2, scores)
            ids = jnp.where(sel, i2, ids)
        return scores, ids

    def search_predicate(self, queries: np.ndarray, pred: BoxPredicate):
        """Range/disjunctive predicate -> multi-probe (§4.3)."""
        probes = np.asarray(pred.probes(self.cfg.multi_probe_r))  # (r, m)
        n = queries.shape[0]
        fp = jnp.broadcast_to(jnp.asarray(probes)[None],
                              (n, *probes.shape))
        return fcvi.multi_probe_query(self.index, jnp.asarray(queries), fp,
                                      self.cfg.k)

    # -- updates ----------------------------------------------------------
    def insert(self, vectors: np.ndarray, filters: np.ndarray):
        self._delta_v.append(np.asarray(vectors, np.float32))
        self._delta_f.append(np.asarray(filters, np.float32))
        self.stats.inserts += len(vectors)
        self._cache.clear()  # results may change
        if sum(len(v) for v in self._delta_v) >= self.cfg.compact_threshold:
            self.compact()

    def delta_size(self) -> int:
        return sum(len(v) for v in self._delta_v)

    def compact(self):
        if not self._delta_v:
            return
        v = np.concatenate(self._delta_v)
        f = np.concatenate(self._delta_f)
        self.index = fcvi.extend(self.index, jnp.asarray(v), jnp.asarray(f))
        self._delta_v, self._delta_f = [], []
        self.stats.compactions += 1

    def _merge_delta(self, q, f, scores, ids, k):
        """Exact search over the (small) delta buffer, merged into results."""
        if not self._delta_v:
            return scores, ids
        dv = np.concatenate(self._delta_v)
        df = np.concatenate(self._delta_f)
        tfm = self.index.transform
        qn = np.asarray(tfm.vec_norm.apply(jnp.asarray(q[None])))[0]
        fqn = np.asarray(tfm.filt_norm.apply(jnp.asarray(f[None])))[0]
        dvn = np.asarray(tfm.vec_norm.apply(jnp.asarray(dv)))
        dfn = np.asarray(tfm.filt_norm.apply(jnp.asarray(df)))

        def cos(a, b):
            return (a @ b) / (np.linalg.norm(a, axis=-1) * np.linalg.norm(b) + 1e-8)

        lam = self.index.config.lam
        s = lam * cos(dvn, qn) + (1 - lam) * cos(dfn, fqn)
        base = self.index.size
        all_s = np.concatenate([scores, s])
        all_i = np.concatenate([ids, base + np.arange(len(s))])
        top = np.argsort(-all_s)[:k]
        return all_s[top].astype(np.float32), all_i[top]
