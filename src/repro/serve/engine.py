"""Batched FCVI serving engine (§4.3 optimizations, production shape).

Implements the paper's serving-side optimizations on top of FCVIIndex:
  * request batching (group queries, amortise index traversal),
  * filter-aware result cache (common filter combinations hit the cache;
    cache keys are quantized once per batch with a single vectorized round),
  * adaptive k' with two-stage escalation (early-termination dual: retrieve
    with a small k', escalate only queries whose top-k margin is ambiguous),
  * delta buffer for inserts + background compaction: new rows live in a
    device-resident delta ``FlatIndex`` (transformed space) between
    compactions,
  * multi-probe execution for range/disjunctive predicates.

The per-batch hot path — normalize + transform the queries, backend candidate
generation, combined-score re-rank, delta search + ``merge_topk``, and the
escalation margin — is ONE ``jax.jit``-compiled function (``_batch_step``)
over statically padded batch shapes: a batch costs a single dispatch, not a
Python re-entry per stage. Cache lookups, stats, and the escalation decision
are host-side bookkeeping OFF the traced path; ``trace_count()`` exposes the
compile counter so tests can pin down per-batch retracing regressions.

When ``FCVIConfig.use_pallas`` is set on the wrapped index, everything inside
the step — the fused query transform, candidate generation, re-scoring, and
the delta merge — runs through the Pallas kernels in ``repro.kernels.ops``.

Mesh-sharded serving: constructing the engine with a ``jax.sharding.Mesh``
(``FCVIEngine(index, cfg, mesh=mesh)``) shards the serving state over the
device mesh and replaces the batch step with the ``shard_map`` step from
``repro.serve.sharded`` — flat slabs row-sharded, IVF slabs list-sharded,
the delta buffer row-sharded, candidates tree-merged per mesh axis. Results
are IDENTICAL to the single-device step for any mesh shape (a 1-device mesh
is the trivial case); ``mesh=None`` (the default) keeps the single-device
``_batch_step``.

Lifecycle: ``engine.save(ckpt_dir)`` checkpoints the full serving state
(transform + backend slab source arrays + re-rank originals + pending delta
rows) through ``repro.checkpoint.ckpt``; ``FCVIEngine.restore(ckpt_dir,
mesh=...)`` rebuilds an engine on ANY target mesh — arrays are loaded
replicated on host and re-laid-out by the sharding step, which is the
elastic-restart path (build on 8 devices, restore and serve on 2).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as ckpt_mod
from repro.core import fcvi, theory
from repro.core.baselines import BoxPredicate
from repro.core.fcvi import FCVIConfig, FCVIIndex
from repro.index import flat as flat_mod

# incremented at TRACE time inside _batch_step: stable across steady-state
# batches of the same padded shape, so tests can assert "no silent retracing"
_TRACE_COUNT = [0]


def trace_count() -> int:
    """How many times the jitted engine batch step has been (re)traced."""
    return _TRACE_COUNT[0]


@partial(jax.jit, static_argnames=("k", "kp", "kd"))
def _batch_step(index: FCVIIndex, delta_vn, delta_fn, delta_flat, q, f,
                *, k: int, kp: int, kd: int):
    """The whole per-batch hot path as one traced computation.

    transform -> backend candidate generation -> combined-score re-rank ->
    delta search + merge_topk -> escalation margin. ``delta_*`` are None when
    no inserts are pending (a distinct, equally static trace). Returns
    (scores (b,k), ids (b,k), margin (b,)).
    """
    _TRACE_COUNT[0] += 1            # trace-time side effect: counts compiles
    cfg = index.config
    qn, fqn = index.transform.normalize(q, f)
    q_t = index.transform.apply_normalized(qn, fqn, use_pallas=cfg.use_pallas)
    _, cand = fcvi._backend_search(index, q_t, kp)
    scores, ids = fcvi.rescore(index, qn, fqn, cand, k)

    if delta_flat is not None:
        # same over-retrieval bound as the main path (Thm 5.4), so pruning
        # the delta in transformed space never costs more recall than the
        # backend search does; q_t is reused — the fused transform runs once
        nd = delta_vn.shape[0]
        if kd < nd:
            _, dcand = flat_mod.search(delta_flat, q_t, kd,
                                       use_pallas=cfg.use_pallas)
        else:
            dcand = jnp.broadcast_to(jnp.arange(nd)[None, :],
                                     (q.shape[0], nd))
        s = fcvi.combined_score(delta_vn[dcand], delta_fn[dcand], qn, fqn,
                                cfg.lam, use_pallas=cfg.use_pallas)
        dvals, dpos = jax.lax.top_k(s, min(k, kd))
        dids = index.size + jnp.take_along_axis(dcand, dpos, axis=-1)
        scores, ids = flat_mod.merge_topk(scores, ids, dvals,
                                          dids.astype(ids.dtype), k)

    margin = scores[:, 0] - scores[:, -1]
    return scores, ids, margin


@dataclasses.dataclass
class EngineConfig:
    k: int = 10
    batch_size: int = 64
    cache_entries: int = 4096
    cache_round: float = 0.05      # filter-key quantization for cache hits
    escalate_margin: float = 0.02  # top-k score margin triggering stage 2
    kprime_escalation: int = 4     # stage-2 k' multiplier
    compact_threshold: int = 2048  # delta rows triggering compaction
    multi_probe_r: int = 4


@dataclasses.dataclass
class EngineStats:
    queries: int = 0
    cache_hits: int = 0
    escalations: int = 0
    inserts: int = 0
    compactions: int = 0
    total_time_s: float = 0.0

    @property
    def qps(self) -> float:
        return self.queries / self.total_time_s if self.total_time_s else 0.0


@dataclasses.dataclass
class _DeltaBuffer:
    """Device-resident view of the un-compacted inserts."""

    vn: jax.Array        # (nd, d) normalized new vectors
    fn: jax.Array        # (nd, m) normalized new filters
    flat: flat_mod.FlatIndex  # transformed-space index over the delta rows


class FCVIEngine:
    def __init__(self, index: FCVIIndex, config: Optional[EngineConfig] = None,
                 *, mesh=None, rules=None, placement: str = "contiguous"):
        self.index = index
        # default constructed per engine: a shared EngineConfig() default
        # instance would leak mutations across engines
        self.cfg = config if config is not None else EngineConfig()
        self.stats = EngineStats()
        self._cache: "collections.OrderedDict" = collections.OrderedDict()
        self._delta_v: list = []
        self._delta_f: list = []
        self._delta: Optional[_DeltaBuffer] = None
        self._mesh, self._rules, self._placement = mesh, rules, placement
        self._sharded = None
        self._sharded_delta = None
        if mesh is not None:
            self._build_sharded()

    def _build_sharded(self):
        """(Re)shard the serving state onto the configured mesh."""
        from repro.serve.sharded import ShardedServing

        self._sharded = ShardedServing(self.index, self._mesh,
                                       rules=self._rules,
                                       placement=self._placement)
        self._sharded_delta = None

    # -- cache ------------------------------------------------------------
    def _cache_keys(self, queries: np.ndarray,
                    filters: np.ndarray) -> List[bytes]:
        """Quantized keys for the whole batch: one vectorized round."""
        r = self.cfg.cache_round
        qq = np.round(queries / r).astype(np.int32)
        ff = np.round(filters / r).astype(np.int32)
        return [q.tobytes() + b"#" + f.tobytes() for q, f in zip(qq, ff)]

    def _cache_get(self, key: bytes):
        if key in self._cache:
            self._cache.move_to_end(key)
            return self._cache[key]
        return None

    def _cache_put(self, key: bytes, value):
        self._cache[key] = value
        self._cache.move_to_end(key)
        while len(self._cache) > self.cfg.cache_entries:
            self._cache.popitem(last=False)

    # -- search -----------------------------------------------------------
    def search(self, queries: np.ndarray, filters: np.ndarray):
        """queries: (n, d); filters: (n, m). Returns (scores, ids) (n, k)."""
        t0 = time.perf_counter()
        n = queries.shape[0]
        k = self.cfg.k
        out_scores = np.zeros((n, k), np.float32)
        out_ids = np.zeros((n, k), np.int64)

        keys = self._cache_keys(queries, filters)
        todo = []
        for i, key in enumerate(keys):
            hit = self._cache_get(key)
            if hit is not None:
                out_scores[i], out_ids[i] = hit
                self.stats.cache_hits += 1
            else:
                todo.append(i)

        bs = self.cfg.batch_size
        for s in range(0, len(todo), bs):
            idxs = todo[s:s + bs]
            pad = bs - len(idxs)
            q = np.concatenate([queries[idxs],
                                np.zeros((pad, queries.shape[1]), np.float32)])
            f = np.concatenate([filters[idxs],
                                np.zeros((pad, filters.shape[1]), np.float32)])
            qj, fj = jnp.asarray(q), jnp.asarray(f)
            scores, ids = self._run_batch(qj, fj, k, n_real=len(idxs))
            scores, ids = np.asarray(scores), np.asarray(ids)
            for j, i in enumerate(idxs):
                out_scores[i], out_ids[i] = scores[j], ids[j]
                self._cache_put(keys[i], (scores[j], ids[j]))

        self.stats.queries += n
        self.stats.total_time_s += time.perf_counter() - t0
        return out_scores, out_ids

    def _run_batch(self, q, f, k, n_real: Optional[int] = None):
        """One padded batch through the jitted step; escalation decided here
        (host-side bookkeeping), each stage a single compiled dispatch.

        Stage 2 runs ONLY the escalated queries, gathered into a padded
        power-of-two sub-batch (so trace shapes stay bounded: one cached
        trace per bucket size) and scattered back — with the typical few-
        percent escalation rate this makes stage 2 nearly free instead of
        re-running the whole batch at ~4x k'. ``n_real`` caps escalation to
        the real rows of a padded batch: zero-filler rows have data-dependent
        margins and must not trigger (or count as) escalations.
        """
        cfg = self.index.config
        alpha = cfg.resolved_alpha()
        kp = theory.k_prime(k, cfg.lam, alpha, self.index.size, cfg.c)
        delta = self._ensure_delta()
        dvn = dfn = dflat = None
        kd = 0
        if delta is not None:
            nd = delta.vn.shape[0]
            kdp = theory.k_prime(k, cfg.lam, alpha, nd, cfg.c)
            kd = min(nd, max(kdp, 4 * k))
            dvn, dfn, dflat = delta.vn, delta.fn, delta.flat
        scores, ids, margin = self._step(dvn, dfn, dflat, q, f,
                                         k=k, kp=kp, kd=kd)
        need = np.asarray(margin < self.cfg.escalate_margin)
        if n_real is not None:
            need = need[:n_real]
        if need.any():
            idxs = np.nonzero(need)[0]
            self.stats.escalations += len(idxs)
            kp2 = theory.k_prime(k, cfg.lam, alpha, self.index.size,
                                 cfg.c * self.cfg.kprime_escalation)
            nb = q.shape[0]
            while nb // 2 >= max(len(idxs), 1):
                nb //= 2
            sel = np.zeros((nb,), np.int64)
            sel[: len(idxs)] = idxs            # pad slots recompute query 0
            sel_j = jnp.asarray(sel)
            s2, i2, _ = self._step(dvn, dfn, dflat,
                                   q[sel_j], f[sel_j], k=k, kp=kp2, kd=kd)
            take = jnp.asarray(idxs)
            scores = scores.at[take].set(s2[: len(idxs)])
            ids = ids.at[take].set(i2[: len(idxs)])
        return scores, ids

    def _step(self, dvn, dfn, dflat, q, f, *, k: int, kp: int, kd: int):
        """Dispatch one padded batch to the single-device jitted step or the
        mesh-sharded shard_map step (identical results by construction)."""
        if self._sharded is None:
            return _batch_step(self.index, dvn, dfn, dflat, q, f,
                               k=k, kp=kp, kd=kd)
        sdelta = None
        if dflat is not None:
            if self._sharded_delta is None:
                self._sharded_delta = self._sharded.shard_delta(self._delta)
            sdelta = self._sharded_delta
        return self._sharded.step(sdelta, q, f, k=k, kp=kp, kd=kd)

    def _staged_query(self, q, f, k):
        """Pre-jit two-stage query WITHOUT the delta merge — kept as the
        faithful legacy baseline for benchmarks/query_path.py."""
        scores, ids = fcvi.query(self.index, q, f, k)
        margin = scores[:, 0] - scores[:, -1]
        need = np.asarray(margin < self.cfg.escalate_margin)
        if need.any():
            self.stats.escalations += int(need.sum())
            cfg = self.index.config
            kp2 = theory.k_prime(k, cfg.lam, cfg.resolved_alpha(),
                                 self.index.size,
                                 cfg.c * self.cfg.kprime_escalation)
            s2, i2 = fcvi.query(self.index, q, f, k, k_prime=kp2)
            sel = jnp.asarray(need)[:, None]
            scores = jnp.where(sel, s2, scores)
            ids = jnp.where(sel, i2, ids)
        return scores, ids

    def search_predicate(self, queries: np.ndarray, pred: BoxPredicate):
        """Range/disjunctive predicate -> multi-probe (§4.3)."""
        probes = np.asarray(pred.probes(self.cfg.multi_probe_r))  # (r, m)
        n = queries.shape[0]
        fp = jnp.broadcast_to(jnp.asarray(probes)[None],
                              (n, *probes.shape))
        return fcvi.multi_probe_query(self.index, jnp.asarray(queries), fp,
                                      self.cfg.k)

    # -- updates ----------------------------------------------------------
    def insert(self, vectors: np.ndarray, filters: np.ndarray):
        self._delta_v.append(np.asarray(vectors, np.float32))
        self._delta_f.append(np.asarray(filters, np.float32))
        self.stats.inserts += len(vectors)
        self._cache.clear()  # results may change
        self._delta = None   # invalidate; rebuilt lazily on the next search
        self._sharded_delta = None
        if sum(len(v) for v in self._delta_v) >= self.cfg.compact_threshold:
            self.compact()

    def delta_size(self) -> int:
        return sum(len(v) for v in self._delta_v)

    def _ensure_delta(self) -> Optional[_DeltaBuffer]:
        """Materialise the device-resident delta buffer on first use after an
        insert (lazy, so back-to-back inserts cost nothing until a query)."""
        if self._delta is None and self._delta_v:
            cfg = self.index.config
            tfm = self.index.transform
            vn = tfm.vec_norm.apply(jnp.asarray(np.concatenate(self._delta_v)))
            fn = tfm.filt_norm.apply(jnp.asarray(np.concatenate(self._delta_f)))
            self._delta = _DeltaBuffer(
                vn=vn, fn=fn,
                flat=flat_mod.build(tfm.apply_normalized(vn, fn),
                                    storage_dtype=cfg.resolved_storage_dtype()))
        return self._delta

    def compact(self):
        if not self._delta_v:
            return
        v = np.concatenate(self._delta_v)
        f = np.concatenate(self._delta_f)
        self.index = fcvi.extend(self.index, jnp.asarray(v), jnp.asarray(f))
        self._delta_v, self._delta_f = [], []
        self._delta = None
        self._sharded_delta = None
        if self._sharded is not None:
            self._build_sharded()   # re-shard the grown slabs onto the mesh
        self.stats.compactions += 1

    # -- checkpoint lifecycle ---------------------------------------------
    def save(self, ckpt_dir: str, step: int = 0, keep: int = 3) -> str:
        """Checkpoint the full serving state (build -> checkpoint -> restore
        -> serve lifecycle).

        Saves the transform + backend source arrays + re-rank originals via
        ``fcvi.index_state`` (derived serving slabs are rebuilt at restore
        time by the slab layer) plus any PENDING delta rows, with the static
        configs in the manifest metadata. Sharded arrays are gathered to host
        transparently by the checkpoint writer.
        """
        d = self.index.transform.vec_norm.mean.shape[-1]
        m = self.index.transform.filt_norm.mean.shape[-1]
        dv = (np.concatenate(self._delta_v) if self._delta_v
              else np.zeros((0, d), np.float32))
        df = (np.concatenate(self._delta_f) if self._delta_f
              else np.zeros((0, m), np.float32))
        tree = {"index": fcvi.index_state(self.index),
                "delta_v": dv, "delta_f": df}
        metadata = {
            "fcvi_config": dataclasses.asdict(self.index.config),
            "engine_config": dataclasses.asdict(self.cfg),
        }
        return ckpt_mod.save(ckpt_dir, step, tree, metadata=metadata,
                             keep=keep)

    @classmethod
    def restore(cls, ckpt_dir: str, *, step: Optional[int] = None,
                config: Optional[EngineConfig] = None, mesh=None, rules=None,
                placement: str = "contiguous") -> "FCVIEngine":
        """Restore an engine from a checkpoint onto ANY target mesh.

        The elastic-restart path: arrays come back replicated on host, the
        index is rebuilt without re-training (k-means state is part of the
        checkpoint), and — when ``mesh`` is given — the slab layer re-lays
        the serving state out over the TARGET mesh, which may have a
        different shape than the mesh the checkpoint was written from.
        """
        tree, _, metadata = ckpt_mod.load(ckpt_dir, step=step)
        fcfg = FCVIConfig(**metadata["fcvi_config"])
        index = fcvi.index_from_state(fcfg, tree["index"])
        ecfg = (config if config is not None
                else EngineConfig(**metadata["engine_config"]))
        eng = cls(index, ecfg, mesh=mesh, rules=rules, placement=placement)
        if tree["delta_v"].shape[0]:
            eng._delta_v = [np.asarray(tree["delta_v"], np.float32)]
            eng._delta_f = [np.asarray(tree["delta_f"], np.float32)]
            eng.stats.inserts = int(tree["delta_v"].shape[0])
        return eng
