"""Batched FCVI serving engine (§4.3 optimizations, production shape).

Implements the paper's serving-side optimizations on top of FCVIIndex:
  * request batching (group queries, amortise index traversal),
  * filter-aware result cache (common filter combinations hit the cache;
    cache keys are quantized once per batch with a single vectorized round),
  * adaptive k' with two-stage escalation (early-termination dual: retrieve
    with a small k', escalate only queries whose top-k margin is ambiguous),
  * delta buffer for inserts + background compaction: new rows live in a
    device-resident delta ``FlatIndex`` (transformed space) between
    compactions; every batch runs ONE jnp exact search + fused combined-score
    pass over the delta and merges it into the main results with
    ``merge_topk`` — no per-query host loops anywhere on the hot path,
  * multi-probe execution for range/disjunctive predicates.

When ``FCVIConfig.use_pallas`` is set on the wrapped index, the whole path —
backend candidate generation, re-scoring, and the delta merge — runs through
the Pallas kernels in ``repro.kernels.ops``.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fcvi, theory
from repro.core.baselines import BoxPredicate
from repro.core.fcvi import FCVIConfig, FCVIIndex
from repro.index import flat as flat_mod


@dataclasses.dataclass
class EngineConfig:
    k: int = 10
    batch_size: int = 64
    cache_entries: int = 4096
    cache_round: float = 0.05      # filter-key quantization for cache hits
    escalate_margin: float = 0.02  # top-k score margin triggering stage 2
    kprime_escalation: int = 4     # stage-2 k' multiplier
    compact_threshold: int = 2048  # delta rows triggering compaction
    multi_probe_r: int = 4


@dataclasses.dataclass
class EngineStats:
    queries: int = 0
    cache_hits: int = 0
    escalations: int = 0
    inserts: int = 0
    compactions: int = 0
    total_time_s: float = 0.0

    @property
    def qps(self) -> float:
        return self.queries / self.total_time_s if self.total_time_s else 0.0


@dataclasses.dataclass
class _DeltaBuffer:
    """Device-resident view of the un-compacted inserts."""

    vn: jax.Array        # (nd, d) normalized new vectors
    fn: jax.Array        # (nd, m) normalized new filters
    flat: flat_mod.FlatIndex  # transformed-space index over the delta rows


class FCVIEngine:
    def __init__(self, index: FCVIIndex, config: EngineConfig = EngineConfig()):
        self.index = index
        self.cfg = config
        self.stats = EngineStats()
        self._cache: "collections.OrderedDict" = collections.OrderedDict()
        self._delta_v: list = []
        self._delta_f: list = []
        self._delta: Optional[_DeltaBuffer] = None

    # -- cache ------------------------------------------------------------
    def _cache_keys(self, queries: np.ndarray,
                    filters: np.ndarray) -> List[bytes]:
        """Quantized keys for the whole batch: one vectorized round."""
        r = self.cfg.cache_round
        qq = np.round(queries / r).astype(np.int32)
        ff = np.round(filters / r).astype(np.int32)
        return [q.tobytes() + b"#" + f.tobytes() for q, f in zip(qq, ff)]

    def _cache_get(self, key: bytes):
        if key in self._cache:
            self._cache.move_to_end(key)
            return self._cache[key]
        return None

    def _cache_put(self, key: bytes, value):
        self._cache[key] = value
        self._cache.move_to_end(key)
        while len(self._cache) > self.cfg.cache_entries:
            self._cache.popitem(last=False)

    # -- search -----------------------------------------------------------
    def search(self, queries: np.ndarray, filters: np.ndarray):
        """queries: (n, d); filters: (n, m). Returns (scores, ids) (n, k)."""
        t0 = time.perf_counter()
        n = queries.shape[0]
        k = self.cfg.k
        out_scores = np.zeros((n, k), np.float32)
        out_ids = np.zeros((n, k), np.int64)

        keys = self._cache_keys(queries, filters)
        todo = []
        for i, key in enumerate(keys):
            hit = self._cache_get(key)
            if hit is not None:
                out_scores[i], out_ids[i] = hit
                self.stats.cache_hits += 1
            else:
                todo.append(i)

        bs = self.cfg.batch_size
        for s in range(0, len(todo), bs):
            idxs = todo[s:s + bs]
            pad = bs - len(idxs)
            q = np.concatenate([queries[idxs],
                                np.zeros((pad, queries.shape[1]), np.float32)])
            f = np.concatenate([filters[idxs],
                                np.zeros((pad, filters.shape[1]), np.float32)])
            qj, fj = jnp.asarray(q), jnp.asarray(f)
            scores, ids = self._staged_query(qj, fj, k)
            scores, ids = self._merge_delta_batch(qj, fj, scores, ids, k)
            scores, ids = np.asarray(scores), np.asarray(ids)
            for j, i in enumerate(idxs):
                out_scores[i], out_ids[i] = scores[j], ids[j]
                self._cache_put(keys[i], (scores[j], ids[j]))

        self.stats.queries += n
        self.stats.total_time_s += time.perf_counter() - t0
        return out_scores, out_ids

    def _staged_query(self, q, f, k):
        scores, ids = fcvi.query(self.index, q, f, k)
        margin = scores[:, 0] - scores[:, -1]
        need = np.asarray(margin < self.cfg.escalate_margin)
        if need.any():
            self.stats.escalations += int(need.sum())
            cfg = self.index.config
            kp2 = theory.k_prime(k, cfg.lam, cfg.resolved_alpha(),
                                 self.index.size,
                                 cfg.c * self.cfg.kprime_escalation)
            s2, i2 = fcvi.query(self.index, q, f, k, k_prime=kp2)
            sel = jnp.asarray(need)[:, None]
            scores = jnp.where(sel, s2, scores)
            ids = jnp.where(sel, i2, ids)
        return scores, ids

    def search_predicate(self, queries: np.ndarray, pred: BoxPredicate):
        """Range/disjunctive predicate -> multi-probe (§4.3)."""
        probes = np.asarray(pred.probes(self.cfg.multi_probe_r))  # (r, m)
        n = queries.shape[0]
        fp = jnp.broadcast_to(jnp.asarray(probes)[None],
                              (n, *probes.shape))
        return fcvi.multi_probe_query(self.index, jnp.asarray(queries), fp,
                                      self.cfg.k)

    # -- updates ----------------------------------------------------------
    def insert(self, vectors: np.ndarray, filters: np.ndarray):
        self._delta_v.append(np.asarray(vectors, np.float32))
        self._delta_f.append(np.asarray(filters, np.float32))
        self.stats.inserts += len(vectors)
        self._cache.clear()  # results may change
        self._delta = None   # invalidate; rebuilt lazily on the next search
        if sum(len(v) for v in self._delta_v) >= self.cfg.compact_threshold:
            self.compact()

    def delta_size(self) -> int:
        return sum(len(v) for v in self._delta_v)

    def _ensure_delta(self) -> Optional[_DeltaBuffer]:
        """Materialise the device-resident delta buffer on first use after an
        insert (lazy, so back-to-back inserts cost nothing until a query)."""
        if self._delta is None and self._delta_v:
            tfm = self.index.transform
            vn = tfm.vec_norm.apply(jnp.asarray(np.concatenate(self._delta_v)))
            fn = tfm.filt_norm.apply(jnp.asarray(np.concatenate(self._delta_f)))
            self._delta = _DeltaBuffer(
                vn=vn, fn=fn,
                flat=flat_mod.build(tfm.apply_normalized(vn, fn)))
        return self._delta

    def compact(self):
        if not self._delta_v:
            return
        v = np.concatenate(self._delta_v)
        f = np.concatenate(self._delta_f)
        self.index = fcvi.extend(self.index, jnp.asarray(v), jnp.asarray(f))
        self._delta_v, self._delta_f = [], []
        self._delta = None
        self.stats.compactions += 1

    def _merge_delta_batch(self, q, f, scores, ids, k):
        """One batched exact search over the delta buffer, merged into results.

        Candidate pruning uses the transformed-space delta FlatIndex (itself
        kernel-backed when use_pallas is on); the survivors get the exact
        fused combined-cosine score and merge into the main top-k with
        ``merge_topk``. Entirely device-side — no per-query numpy.
        """
        delta = self._ensure_delta()
        if delta is None:
            return scores, ids
        cfg = self.index.config
        tfm = self.index.transform
        nd = delta.vn.shape[0]
        qn = tfm.vec_norm.apply(q)
        fqn = tfm.filt_norm.apply(f)

        # same over-retrieval bound as the main path (Thm 5.4), so pruning
        # the delta in transformed space never costs more recall than the
        # backend search does
        kp = theory.k_prime(k, cfg.lam, cfg.resolved_alpha(), nd, cfg.c)
        kd = min(nd, max(kp, 4 * k))
        if kd < nd:
            q_t = tfm.apply_normalized(qn, fqn)
            _, cand = flat_mod.search(delta.flat, q_t, kd,
                                      use_pallas=cfg.use_pallas)
        else:
            cand = jnp.broadcast_to(jnp.arange(nd)[None, :],
                                    (q.shape[0], nd))
        s = fcvi.combined_score(delta.vn[cand], delta.fn[cand], qn, fqn,
                                cfg.lam, use_pallas=cfg.use_pallas)
        dvals, dpos = jax.lax.top_k(s, min(k, kd))
        dids = self.index.size + jnp.take_along_axis(cand, dpos, axis=-1)
        return flat_mod.merge_topk(scores, ids, dvals,
                                   dids.astype(ids.dtype), k)
