"""Batched FCVI serving engine (§4.3 optimizations, production shape).

Implements the paper's serving-side optimizations on top of FCVIIndex:
  * request batching (group queries, amortise index traversal),
  * filter-aware result cache (common filter combinations hit the cache;
    cache keys are quantized once per batch with a single vectorized round),
  * adaptive k' with two-stage escalation (early-termination dual: retrieve
    with a small k', escalate only queries whose top-k margin is ambiguous),
  * delta buffer for inserts + background compaction: new rows live in a
    device-resident delta ``FlatIndex`` (transformed space) between
    compactions,
  * multi-probe execution for range/disjunctive predicates.

The per-batch hot path — normalize + transform the queries, backend candidate
generation, combined-score re-rank, delta search + ``merge_topk``, and the
escalation margin — is ONE ``jax.jit``-compiled function (``_batch_step``)
over statically padded batch shapes: a batch costs a single dispatch, not a
Python re-entry per stage. Cache lookups, stats, and the escalation decision
are host-side bookkeeping OFF the traced path; ``trace_count()`` exposes the
compile counter so tests can pin down per-batch retracing regressions.

When ``FCVIConfig.use_pallas`` is set on the wrapped index, everything inside
the step — the fused query transform, candidate generation, re-scoring, and
the delta merge — runs through the Pallas kernels in ``repro.kernels.ops``.

Mesh-sharded serving: constructing the engine with a ``jax.sharding.Mesh``
(``FCVIEngine(index, cfg, mesh=mesh)``) shards the serving state over the
device mesh and replaces the batch step with the ``shard_map`` step from
``repro.serve.sharded`` — flat slabs row-sharded, IVF slabs list-sharded,
the delta buffer row-sharded, candidates tree-merged per mesh axis. Results
are IDENTICAL to the single-device step for any mesh shape (a 1-device mesh
is the trivial case); ``mesh=None`` (the default) keeps the single-device
``_batch_step``.

Routed serving: ``FCVIEngine(..., mesh=mesh, placement="cluster",
routing="routed")`` turns filter-centric placement into a throughput lever —
the sharded step routes each query to the shards owning its nearby
psi-clusters (flat) or probed inverted lists (IVF) and unrouted shards skip
candidate generation entirely. The dispatch layer sorts each cache-miss
queue by router signature so co-routed queries share batches, and any query
whose routed clipping bound cannot certify exactness is transparently
re-run through the dense step (``stats.router_fallbacks``), keeping routed
results identical to dense results end to end.

Lifecycle: ``engine.save(ckpt_dir)`` checkpoints the full serving state
(transform + backend slab source arrays + re-rank originals + pending delta
rows) through ``repro.checkpoint.ckpt``; ``FCVIEngine.restore(ckpt_dir,
mesh=...)`` rebuilds an engine on ANY target mesh — arrays are loaded
replicated on host and re-laid-out by the sharding step, which is the
elastic-restart path (build on 8 devices, restore and serve on 2).

Degraded serving: a mesh-backed engine carries a ``ShardHealth`` layer
(``repro.serve.health``) — shards marked dead (operator action, heartbeat
timeout, or straggler eviction) are masked out of the sharded step via its
zero-work ``lax.cond`` branch, results stay bit-identical to a search over
the surviving shards' rows, and queries the dead shards could have answered
carry a coverage flag (``stats.last_coverage`` / ``stats.uncovered_queries``)
instead of silently wrong results. Around the jitted step sits an off-trace
resilience envelope: input hardening at the ``search`` boundary (NaN/Inf,
shape, ``k`` vs corpus), bounded retry with exponential backoff on
``TransientShardError``, a per-batch deadline counter, and queue
backpressure (``BackpressureError`` when the cache-miss queue exceeds
``queue_budget``). ``heal()`` turns the elastic restore into recovery:
checkpoint -> re-place the corpus onto the surviving mesh (placement
preserved) -> bit-identity-validated cutover.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as ckpt_mod
from repro.core import fcvi, theory
from repro.core.baselines import BoxPredicate
from repro.core.fcvi import FCVIConfig, FCVIIndex
from repro.core.filters import Predicate, compile_predicate
from repro.index import flat as flat_mod
from repro.index import ivf as ivf_mod
from repro.kernels import ops
from repro.serve.health import (BackpressureError, ShardHealth,
                                TransientShardError)
from repro.serve.planner import (CANDIDATE_PAD, PLAN_FOLD, PLAN_MASK,
                                 PLAN_ROUTED, PLANS, QueryPlanner,
                                 _pow2_at_least)

# magnitudes beyond this overflow fp32 when squared in the scoring path —
# the input-hardening boundary rejects them as out of support
_SUPPORT_LIMIT = 1e18

# incremented at TRACE time inside _batch_step: stable across steady-state
# batches of the same padded shape, so tests can assert "no silent retracing"
_TRACE_COUNT = [0]


def trace_count() -> int:
    """How many times the jitted engine batch step has been (re)traced."""
    return _TRACE_COUNT[0]


@partial(jax.jit, static_argnames=("k", "kp", "kd"))
def _batch_step(index: FCVIIndex, delta_vn, delta_fn, delta_flat, q, f,
                *, k: int, kp: int, kd: int):
    """The whole per-batch hot path as one traced computation.

    transform -> backend candidate generation -> combined-score re-rank ->
    delta search + merge_topk -> escalation margin. ``delta_*`` are None when
    no inserts are pending (a distinct, equally static trace). Returns
    (scores (b,k), ids (b,k), margin (b,)).
    """
    _TRACE_COUNT[0] += 1            # trace-time side effect: counts compiles
    cfg = index.config
    qn, fqn = index.transform.normalize(q, f)
    q_t = index.transform.apply_normalized(qn, fqn, use_pallas=cfg.use_pallas)
    _, cand = fcvi._backend_search(index, q_t, kp)
    scores, ids = fcvi.rescore(index, qn, fqn, cand, k)

    if delta_flat is not None:
        # same over-retrieval bound as the main path (Thm 5.4), so pruning
        # the delta in transformed space never costs more recall than the
        # backend search does; q_t is reused — the fused transform runs once
        nd = delta_vn.shape[0]
        if kd < nd:
            _, dcand = flat_mod.search(delta_flat, q_t, kd,
                                       use_pallas=cfg.use_pallas)
        else:
            dcand = jnp.broadcast_to(jnp.arange(nd)[None, :],
                                     (q.shape[0], nd))
        s = fcvi.combined_score(delta_vn[dcand], delta_fn[dcand], qn, fqn,
                                cfg.lam, use_pallas=cfg.use_pallas)
        dvals, dpos = jax.lax.top_k(s, min(k, kd))
        dids = index.size + jnp.take_along_axis(dcand, dpos, axis=-1)
        scores, ids = flat_mod.merge_topk(scores, ids, dvals,
                                          dids.astype(ids.dtype), k)

    margin = scores[:, 0] - scores[:, -1]
    return scores, ids, margin


@partial(jax.jit, static_argnames=("k", "kp", "kd"))
def _batch_step_rows(index: FCVIIndex, delta_vn, delta_fn, delta_flat,
                     grouped_pv, grouped_pf, q, f, *, k: int, kp: int,
                     kd: int):
    """Gather-free variant of ``_batch_step`` (flat/IVF backends).

    Candidate generation goes through the rows-returning search entry points
    (``flat.search_rows`` / ``ivf.search_rows``): the winners' re-rank rows
    come straight out of the scoring kernel's VMEM instead of a second
    (b, k') HBM gather from ``vectors_n``/``filters_n``. ``grouped_pv``/
    ``grouped_pf`` are the IVF grouped payload slabs (None for flat — the
    flat payload IS ``vectors_n``/``filters_n`` in corpus order). Results
    are bit-identical to ``_batch_step``: carried rows equal the gathered
    rows bitwise, and unfilled (-inf) slots carry corpus row 0's payload,
    matching the id-0 gather convention.
    """
    _TRACE_COUNT[0] += 1
    cfg = index.config
    qn, fqn = index.transform.normalize(q, f)
    q_t = index.transform.apply_normalized(qn, fqn, use_pallas=cfg.use_pallas)
    if cfg.backend == "ivf":
        _, cand, rv, rf = index.backend.search_rows(
            q_t, kp, index.vectors_n, index.filters_n,
            grouped_pv=grouped_pv, grouped_pf=grouped_pf,
            nprobe=cfg.nprobe, use_pallas=cfg.use_pallas)
    else:
        _, cand, rv, rf = index.backend.search_rows(
            q_t, kp, index.vectors_n, index.filters_n,
            use_pallas=cfg.use_pallas)
    score = fcvi.combined_score(rv, rf, qn, fqn, cfg.lam,
                                use_pallas=cfg.use_pallas)
    scores, pos = jax.lax.top_k(score, k)
    ids = jnp.take_along_axis(cand, pos, axis=-1)

    if delta_flat is not None:
        nd = delta_vn.shape[0]
        if kd < nd:
            _, dcand, drv, drf = flat_mod.search_rows(
                delta_flat, q_t, kd, delta_vn, delta_fn,
                use_pallas=cfg.use_pallas)
        else:
            dcand = jnp.broadcast_to(jnp.arange(nd)[None, :],
                                     (q.shape[0], nd))
            drv, drf = delta_vn[dcand], delta_fn[dcand]
        s = fcvi.combined_score(drv, drf, qn, fqn, cfg.lam,
                                use_pallas=cfg.use_pallas)
        dvals, dpos = jax.lax.top_k(s, min(k, kd))
        dids = index.size + jnp.take_along_axis(dcand, dpos, axis=-1)
        scores, ids = flat_mod.merge_topk(scores, ids, dvals,
                                          dids.astype(ids.dtype), k)

    margin = scores[:, 0] - scores[:, -1]
    return scores, ids, margin


# ---------------------------------------------------------------------------
# Predicate-filtered physical plans (general filter algebra, meshless side).
#
# All three plans funnel into the SAME refine convention — canonical fp32
# elementwise d2 (``flat.filtered_d2``) + deterministic (d2 asc, id asc)
# lexsort + dead slots at (+inf, DEAD_ID) — so any plan whose candidate set
# CONTAINS the true eligible top-k produces bit-identical output. Predicate
# values, eligibility masks, and routed list ids enter as DATA operands; the
# only jit keys are (k, kp, use_pallas) plus the pytree structure, so
# steady-state filtered batches never retrace.
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("k", "kp", "use_pallas"))
def _filtered_mask_step(backend, q_t, elig, *, k: int, kp: int,
                        use_pallas: bool):
    """MASK plan: in-kernel eligibility-masked scan, then filtered refine.

    ``elig`` is a (n,) bool over corpus rows. Flat backends run the masked
    top-k'' scan (``flat.masked_candidates``); IVF backends run the masked
    EXHAUSTIVE all-lists dedup scan (``ivf.masked_candidates``), so the
    candidate set always contains every eligible row within k'' — exact by
    construction when kp >= min(k, #eligible)."""
    _TRACE_COUNT[0] += 1
    if isinstance(backend, flat_mod.FlatIndex):
        cand, valid = flat_mod.masked_candidates(backend, q_t, kp, elig,
                                                 use_pallas=use_pallas)
        vectors, scales = backend.vectors, backend.scales
    else:
        cand, valid = ivf_mod.masked_candidates(backend, q_t, kp, elig,
                                                use_pallas=use_pallas)
        vectors, scales = backend.vectors, backend.scales
    return flat_mod.filtered_refine(vectors, scales, q_t, cand, valid,
                                    elig, k)


@partial(jax.jit, static_argnames=("k", "kp", "use_pallas"))
def _filtered_fold_step(backend, q_t, elig, *, k: int, kp: int,
                        use_pallas: bool):
    """FOLD plan (flat fp32 only): unmasked scan against the folded query.

    ``q_t`` was transformed against the predicate's RAW-space fold target,
    so eligible rows geometrically cluster near the query (the paper's psi
    contraction). We over-retrieve kp unfiltered candidates, refine over the
    eligible subset, and emit a per-query CERTIFICATE: the result is exact
    when the candidate window held >= k eligible rows, or held every
    eligible row there is. Uncertified rows fall back to the MASK plan
    host-side. Returns (d2, ids, certified)."""
    _TRACE_COUNT[0] += 1
    vals, cand = ops.score_topk_padded(backend.vectors, backend.sq_norms,
                                       q_t, kp, scales=backend.scales,
                                       use_pallas=use_pallas)
    valid = ~jnp.isneginf(vals)
    cand = jnp.maximum(cand, 0)
    d2, ids = flat_mod.filtered_refine(backend.vectors, backend.scales,
                                       q_t, cand, valid, elig, k)
    elig_in = jnp.sum(jnp.where(valid, elig[cand], False), axis=-1)
    n_elig = jnp.sum(elig)
    certified = (elig_in >= k) | (elig_in == n_elig)
    return d2, ids, certified


@partial(jax.jit, static_argnames=("k", "kp", "use_pallas"))
def _filtered_routed_step(backend, q_t, elig, uniq, n_live, *, k: int,
                          kp: int, use_pallas: bool):
    """ROUTED plan (IVF meshless): scan only the lists holding eligible rows.

    ``uniq`` is the pow-2-padded live list-id bucket (pads repeat a live id;
    ``n_live`` masks them via the member operand, both DATA). Exact because
    every eligible row lives in some routed list and the dedup scan inside
    is exhaustive over those lists."""
    _TRACE_COUNT[0] += 1
    cand, valid = ivf_mod.routed_candidates(backend, q_t, kp, elig, uniq,
                                            n_live, use_pallas=use_pallas)
    return flat_mod.filtered_refine(backend.vectors, backend.scales, q_t,
                                    cand, valid, elig, k)


@partial(jax.jit, static_argnames=("k",))
def _filtered_delta_step(delta_flat, q_t, delig, *, k: int):
    """Exact filtered top-k over the delta tier (delta-LOCAL ids).

    ``delig`` is eligibility over the pending raw insert rows. Exhaustive
    elementwise d2 over the (small) delta — same canonical expression as the
    main tiers, so the d2-space merge stays bit-stable. The engine maps the
    returned local ids to ``index.size + j``."""
    _TRACE_COUNT[0] += 1
    rows = delta_flat.vectors.astype(jnp.float32)
    if delta_flat.scales is not None:
        rows = rows * delta_flat.scales[:, None]
    nd = rows.shape[0]
    d2 = flat_mod.filtered_d2(q_t, rows)
    d2 = jnp.where(delig[None, :], d2, jnp.inf)
    ids = jnp.where(delig, jnp.arange(nd, dtype=jnp.int32),
                    flat_mod.DEAD_ID)
    return flat_mod.lexsort_topk(d2, jnp.broadcast_to(ids[None, :], d2.shape),
                                 k)


@dataclasses.dataclass
class EngineConfig:
    """Serving-side knobs (all host-side policy; none change result values
    except ``k``). ``router_nprobe`` only matters for ``routing="routed"``
    flat serving: how many psi-clusters the shard router probes per query
    (0 = auto, ~two shards' worth of clusters; smaller = more shards
    skipped but more dense fallbacks)."""

    k: int = 10
    batch_size: int = 64
    cache_entries: int = 4096
    cache_round: float = 0.05      # filter-key quantization for cache hits
    escalate_margin: float = 0.02  # top-k score margin triggering stage 2
    kprime_escalation: int = 4     # stage-2 k' multiplier
    compact_threshold: int = 2048  # delta rows triggering compaction
    multi_probe_r: int = 4
    router_nprobe: int = 0         # routed flat serving: probed psi-clusters
    # gather-free re-rank: candidate generation emits the winners' re-rank
    # ROWS (from VMEM / shard-local payloads) instead of ids that a second
    # HBM gather (single-device) or mask+psum distributed gather (sharded)
    # must resolve. Results are bit-identical either way; False keeps the
    # legacy id-gather step (flat/IVF only; PQ single-device always gathers)
    gather_free: bool = True
    # -- resilience envelope (off-trace; defaults keep behavior unchanged) --
    deadline_s: float = 0.0        # per-batch deadline; 0 disables the check
    max_retries: int = 2           # bounded retry on TransientShardError
    retry_backoff_s: float = 0.05  # base backoff, doubled per retry
    queue_budget: int = 0          # max cache-miss queue; 0 = unlimited
    # straggler-eviction z-threshold for the shard health layer. NOTE the
    # sample-sd z of ONE outlier in a fleet of n is bounded by (n-1)/sqrt(n)
    # (~2.47 for n=8), so small fleets need a threshold below that bound for
    # single-shard stragglers to ever be evictable
    straggler_z: float = 3.0


@dataclasses.dataclass
class EngineStats:
    """Off-trace serving counters. The ``router_*``/``shard*`` fields are
    only advanced by routed sharded engines: ``shard_steps`` counts
    (batch x shard) slots dispatched, ``shards_active`` how many of those
    actually ran candidate generation (the rest took the zero-work branch),
    ``router_fallbacks`` how many queries were re-run dense because the
    routed clipping bound could not certify exactness."""

    queries: int = 0
    cache_hits: int = 0
    escalations: int = 0
    inserts: int = 0
    compactions: int = 0
    total_time_s: float = 0.0
    routed_batches: int = 0
    router_fallbacks: int = 0
    shards_active: int = 0
    shard_steps: int = 0
    # -- storage-bandwidth accounting (off-trace, model-based) ------------
    # HBM bytes the candidate-generation scans streamed, modeled per batch
    # from the index's slab array sizes (flat: the whole slab; IVF: the
    # probed fraction; PQ: the code matrix) — what makes the fp32 -> bf16 ->
    # int8 storage ladder visible as a served-bytes number
    bytes_scanned: int = 0
    scan_batches: int = 0          # batches the bytes model accounted
    # -- degraded serving / resilience envelope ---------------------------
    degraded_batches: int = 0      # batches served with >= 1 dead shard
    uncovered_queries: int = 0     # queries whose coverage flag was raised
    retries: int = 0               # TransientShardError retries
    deadline_misses: int = 0       # batches exceeding cfg.deadline_s
    backpressure_drops: int = 0    # queries shed by BackpressureError
    straggler_evictions: int = 0   # shards evicted by the health layer
    heals: int = 0                 # validated heal() cutovers
    # -- predicate-filtered serving (filter algebra + planner) -------------
    filtered_queries: int = 0      # queries served through search(filter=)
    plan_fold: int = 0             # queries executed under each physical plan
    plan_mask: int = 0
    plan_routed: int = 0
    filtered_fallbacks: int = 0    # FOLD queries re-run under MASK (uncertified)
    # per-query coverage flags of the LAST search call (True = certified
    # unaffected by dead shards; all-True while healthy)
    last_coverage: Optional[np.ndarray] = None

    @property
    def qps(self) -> float:
        return self.queries / self.total_time_s if self.total_time_s else 0.0

    @property
    def bytes_per_query(self) -> float:
        """Modeled scan bytes per served query (cache hits included in the
        denominator — they stream nothing, which is the point of the cache)."""
        return self.bytes_scanned / self.queries if self.queries else 0.0

    @property
    def effective_bandwidth_gbps(self) -> float:
        """Modeled scan bytes / serving wall time, in GB/s: how fast the
        engine streams index storage. Rises along the storage-dtype ladder
        only if the qps gain matches the bytes drop."""
        if not self.total_time_s:
            return 0.0
        return self.bytes_scanned / self.total_time_s / 1e9

    @property
    def shard_skip_rate(self) -> float:
        """Fraction of (batch x shard) slots skipped by routing."""
        if not self.shard_steps:
            return 0.0
        return 1.0 - self.shards_active / self.shard_steps

    @property
    def coverage_rate(self) -> float:
        """Fraction of served queries certified unaffected by dead shards."""
        if not self.queries:
            return 1.0
        return 1.0 - self.uncovered_queries / self.queries


@dataclasses.dataclass
class _DeltaBuffer:
    """Device-resident view of the un-compacted inserts."""

    vn: jax.Array        # (nd, d) normalized new vectors
    fn: jax.Array        # (nd, m) normalized new filters
    flat: flat_mod.FlatIndex  # transformed-space index over the delta rows


class FCVIEngine:
    """Batched serving engine over one ``FCVIIndex``.

    Core entry points (all take/return HOST numpy arrays):
      * ``search(queries (n, d) fp32, filters (n, m) fp32)`` ->
        (scores (n, k) fp32, ids (n, k) int64) — ids >= ``index.size`` are
        un-compacted delta rows.
      * ``insert(vectors (n, d), filters (n, m))`` — buffered in the delta
        index until ``compact_threshold`` triggers compaction.
      * ``save(dir)`` / ``FCVIEngine.restore(dir, mesh=...)`` — the elastic
        checkpoint lifecycle (any target mesh).

    Dispatch-changing knobs: the wrapped index's ``FCVIConfig.use_pallas``
    (Pallas kernels vs jnp reference inside the step — identical results)
    and ``storage_dtype`` (bf16 corpus slabs); the constructor's ``mesh``
    (``None`` = single-device jitted step, a ``jax.sharding.Mesh`` = the
    shard_map step from ``repro.serve.sharded``), ``placement``
    ("contiguous" row order vs "cluster" filter-centric packing), and
    ``routing`` ("dense" = every shard scans every batch, "routed" = shards
    irrelevant to a query's psi-clusters/probed lists are masked and skip
    their scan; requires a mesh, and ``placement="cluster"`` for the flat
    backend). All four are pure deployment knobs: results are identical
    across every combination (routed mode re-runs queries dense whenever its
    clipping bound cannot certify exactness).
    """

    def __init__(self, index: FCVIIndex, config: Optional[EngineConfig] = None,
                 *, mesh=None, rules=None, placement: str = "contiguous",
                 routing: str = "dense", router_centers=None,
                 attributes=None, attr_names=None):
        self.index = index
        # default constructed per engine: a shared EngineConfig() default
        # instance would leak mutations across engines
        self.cfg = config if config is not None else EngineConfig()
        self.stats = EngineStats()
        self._cache: "collections.OrderedDict" = collections.OrderedDict()
        self._delta_v: list = []
        self._delta_f: list = []
        self._delta: Optional[_DeltaBuffer] = None
        self._mesh, self._rules, self._placement = mesh, rules, placement
        self._grouped_payload = None  # IVF gather-free payload slabs (lazy)
        # predicate-filtered serving state: the RAW attribute table (defaults
        # to the de-normalized filter columns the index was built from), its
        # column names, and the selectivity-aware query planner
        self._init_attrs(attributes, attr_names)
        if routing not in ("dense", "routed"):
            raise ValueError(
                f"routing must be 'dense' or 'routed', got {routing!r}")
        if routing == "routed" and mesh is None:
            raise ValueError("routing='routed' requires a device mesh")
        self._routing = routing
        self._router_centers = router_centers
        self._sharded = None
        self._sharded_delta = None
        # degraded-serving state: health layer (mesh engines only), the
        # alive-mask signature the cache was filled under, the optional
        # fault injector hook, and the heal cutover lock
        self.health: Optional[ShardHealth] = None
        self.fault_injector = None
        self._alive_sig: Optional[bytes] = None
        self._heal_lock = threading.Lock()
        if mesh is not None:
            self._build_sharded()
            self.health = ShardHealth(self._sharded.n_shards,
                                      straggler_z=self.cfg.straggler_z)

    def _init_attrs(self, attributes, attr_names):
        """Set up the predicate-filtered serving state.

        ``attributes`` is the (n, m) RAW attribute table predicates evaluate
        against; when omitted it defaults to the de-normalized filter columns
        (``fcvi.filters_raw``), so ``F.range("f0", ...)`` works out of the
        box on any index. ``attr_names`` names the columns (default
        ``f0..f{m-1}``). The planner's histograms are built here, once."""
        mf = self.index.transform.filt_norm.mean.shape[-1]
        if attributes is None:
            attrs = np.asarray(fcvi.filters_raw(self.index), np.float32)
        else:
            attrs = np.asarray(attributes, np.float32)
            if attrs.shape != (self.index.size, mf):
                # column count must match the filter dimension: the fold
                # plan's representative vector feeds the filter-side psi
                # transform, and delta rows are predicate-checked against
                # their insert filters
                raise ValueError(
                    f"attributes must be (index.size={self.index.size}, "
                    f"m={mf}); got shape {attrs.shape}")
        m = attrs.shape[1]
        if attr_names is None:
            attr_names = tuple(f"f{j}" for j in range(m))
        else:
            attr_names = tuple(attr_names)
            if len(attr_names) != m:
                raise ValueError(
                    f"attr_names has {len(attr_names)} entries for "
                    f"{m} attribute columns")
        self._attrs_np = attrs
        self._attr_names = attr_names
        self._col_means = attrs.mean(axis=0).astype(np.float32)
        self._rebuild_planner()

    def _rebuild_planner(self):
        cfg = self.index.config
        if cfg.backend in ("flat", "ivf"):
            self.planner = QueryPlanner.build(
                self._attrs_np, backend=cfg.backend,
                storage_fp32=cfg.resolved_storage_dtype() is None,
                sharded=self._mesh is not None)
        else:
            self.planner = None  # PQ: no filtered plans

    def _build_sharded(self):
        """(Re)shard the serving state onto the configured mesh."""
        from repro.serve.sharded import ShardedServing

        attrs = (self._attrs_np
                 if self.index.config.backend in ("flat", "ivf") else None)
        self._sharded = ShardedServing(self.index, self._mesh,
                                       rules=self._rules,
                                       placement=self._placement,
                                       routing=self._routing,
                                       router_nprobe=self.cfg.router_nprobe,
                                       router_centers=self._router_centers,
                                       attrs=attrs)
        self._sharded_delta = None

    @property
    def _routed(self) -> bool:
        return self._sharded is not None and self._routing == "routed"

    # -- cache ------------------------------------------------------------
    def _cache_keys(self, queries: np.ndarray,
                    filters: np.ndarray) -> List[bytes]:
        """Quantized keys for the whole batch: one vectorized round."""
        r = self.cfg.cache_round
        qq = np.round(queries / r).astype(np.int32)
        ff = np.round(filters / r).astype(np.int32)
        return [q.tobytes() + b"#" + f.tobytes() for q, f in zip(qq, ff)]

    def _cache_get(self, key: bytes):
        if key in self._cache:
            self._cache.move_to_end(key)
            return self._cache[key]
        return None

    def _cache_put(self, key: bytes, value):
        self._cache[key] = value
        self._cache.move_to_end(key)
        while len(self._cache) > self.cfg.cache_entries:
            self._cache.popitem(last=False)

    # -- storage-bandwidth accounting (off-trace model) --------------------
    def _batch_scan_bytes(self, b: int) -> int:
        """Modeled HBM bytes candidate generation streams for one padded
        batch of ``b`` queries: flat scans the whole slab (vectors + norms +
        int8 scales), IVF streams the probed fraction of the grouped slabs
        (dedup-capped at nlist), PQ sweeps the code matrix; a pending delta
        adds its flat slab. Off-trace and model-based — it counts the bytes
        the scan semantically reads, which is what the storage-dtype ladder
        changes — so the hot path stays untouched."""
        be = self.index.backend
        cfg = self.index.config
        if cfg.backend == "flat":
            n = be.vectors.nbytes + be.sq_norms.nbytes
            if be.scales is not None:
                n += be.scales.nbytes
        elif cfg.backend == "ivf":
            slab = be.grouped.nbytes + be.grouped_sq.nbytes
            if be.grouped_scales is not None:
                n = slab + be.grouped_scales.nbytes
            else:
                n = slab
            nlist = be.nlist
            probed = min(b * min(cfg.nprobe, nlist), nlist)
            n = (n * probed) // nlist + be.centroids.nbytes
        else:
            n = be.codes.nbytes + be.coarse_ids.nbytes
        delta = self._delta
        if delta is not None:
            n += delta.flat.vectors.nbytes + delta.flat.sq_norms.nbytes
            if delta.flat.scales is not None:
                n += delta.flat.scales.nbytes
        return int(n)

    # -- input hardening ---------------------------------------------------
    def _validate_inputs(self, queries, filters):
        """Reject malformed/poisoned inputs at the serving boundary with
        clear ``ValueError``s instead of producing garbage top-k: NaN/Inf
        values, dimension mismatches, empty batches, out-of-support filter
        magnitudes (they overflow fp32 when squared), and ``k`` exceeding
        the corpus. Returns the inputs as fp32 numpy arrays."""
        q = np.asarray(queries, np.float32)
        f = np.asarray(filters, np.float32)
        if q.ndim != 2 or f.ndim != 2:
            raise ValueError(
                f"queries/filters must be 2-D (n, dim); got shapes "
                f"{np.shape(queries)} / {np.shape(filters)}")
        if q.shape[0] == 0:
            raise ValueError("empty query batch: queries.shape[0] == 0")
        if q.shape[0] != f.shape[0]:
            raise ValueError(
                f"queries and filters disagree on batch size: "
                f"{q.shape[0]} != {f.shape[0]}")
        d = self.index.transform.vec_norm.mean.shape[-1]
        m = self.index.transform.filt_norm.mean.shape[-1]
        if q.shape[1] != d:
            raise ValueError(
                f"query dimension mismatch: got {q.shape[1]}, index expects "
                f"{d}")
        if f.shape[1] != m:
            raise ValueError(
                f"filter dimension mismatch: got {f.shape[1]}, index "
                f"expects {m}")
        if not np.isfinite(q).all():
            raise ValueError("queries contain NaN/Inf values")
        if not np.isfinite(f).all():
            raise ValueError("filters contain NaN/Inf values")
        amax = max(float(np.abs(q).max()), float(np.abs(f).max()))
        if amax > _SUPPORT_LIMIT:
            raise ValueError(
                f"input magnitude {amax:.3g} out of support (> "
                f"{_SUPPORT_LIMIT:.0e}): values overflow fp32 when squared")
        total = self.index.size + self.delta_size()
        if self.cfg.k > total:
            raise ValueError(
                f"k={self.cfg.k} exceeds corpus size {total}")
        return q, f

    def _alive_for_search(self):
        """Snapshot the health layer for one search call.

        Returns ``None`` while every shard is healthy (the fast path — the
        degraded step variant is never even traced), else the (n_shards,)
        bool alive mask as a device array. The result cache is cleared
        whenever the mask changes (cached results were computed over a
        different surviving-row set), and cache use is suspended entirely
        while degraded — coverage flags are per-result state a plain
        (scores, ids) cache entry cannot carry.
        """
        if self.health is None:
            return None
        self.health.check_failures()
        sig = (self.health.alive_mask().tobytes()
               if self.health.any_dead() else None)
        if sig != self._alive_sig:
            self._cache.clear()
            self._alive_sig = sig
        if sig is None:
            return None
        return jnp.asarray(self.health.alive_mask())

    # -- search -----------------------------------------------------------
    def search(self, queries: np.ndarray, filters: Optional[np.ndarray] = None,
               *, filter: Optional[Predicate] = None,
               plan: Optional[str] = None):
        """queries: (n, d) fp32. Two serving modes, selected by the kwargs:

        * SIMILARITY mode (``filters`` (n, m) fp32, raw, un-normalized):
          the paper's combined-score search. Returns (scores (n, k) fp32,
          ids (n, k) int64); ids >= ``index.size`` refer to un-compacted
          delta inserts. In routed mode the cache-miss queue is first
          sorted by router shard-group signature so co-routed queries
          share a padded batch (and unprobed shards actually skip).
        * PREDICATE mode (``filter=F.range("price", 10, 50) &
          F.isin("region", [...])``): exact top-k by L2 restricted to the
          rows satisfying the predicate (see ``repro.core.filters``). The
          selectivity-aware planner picks the physical plan per query
          batch (``plan`` forces one of "fold" / "mask" / "routed");
          scores are negative squared distances against the fold-
          transformed query. Queries with no eligible row return
          (-inf, -1) rows. This path bypasses the result cache (the
          predicate is not part of the cache key).

        Inputs are validated at this boundary (see ``_validate_inputs``).
        With dead shards the engine serves DEGRADED: results are
        bit-identical to a search over the surviving shards' rows and
        ``stats.last_coverage`` flags the queries the dead shards could have
        affected. Raises ``BackpressureError`` when the cache-miss queue
        exceeds ``cfg.queue_budget`` (> 0)."""
        if filter is not None:
            if filters is not None:
                raise ValueError(
                    "pass either filters= (similarity mode) or filter= "
                    "(predicate mode), not both")
            return self._search_filtered(queries, filter, plan=plan)
        if filters is None:
            raise TypeError(
                "search() needs filters= (similarity mode) or filter= "
                "(predicate mode)")
        if plan is not None:
            raise ValueError("plan= only applies to predicate mode (filter=)")
        queries, filters = self._validate_inputs(queries, filters)
        t0 = time.perf_counter()
        n = queries.shape[0]
        k = self.cfg.k
        out_scores = np.zeros((n, k), np.float32)
        out_ids = np.zeros((n, k), np.int64)
        coverage = np.ones((n,), bool)
        alive = self._alive_for_search()
        use_cache = alive is None

        keys = self._cache_keys(queries, filters)
        todo = []
        for i, key in enumerate(keys):
            hit = self._cache_get(key) if use_cache else None
            if hit is not None:
                out_scores[i], out_ids[i] = hit
                self.stats.cache_hits += 1
            else:
                todo.append(i)

        if self.cfg.queue_budget and len(todo) > self.cfg.queue_budget:
            self.stats.backpressure_drops += len(todo)
            raise BackpressureError(
                f"dispatch queue {len(todo)} exceeds queue_budget="
                f"{self.cfg.queue_budget}; shed load and retry")

        if todo and self._routed:
            # dispatch-layer regrouping: bucket the queue by shard-group
            # signature so each padded batch touches as few shards as it can
            sigs = self._sharded.route_signatures(queries[todo], filters[todo])
            order = sorted(range(len(todo)), key=lambda j: sigs[j].tobytes())
            todo = [todo[j] for j in order]

        bs = self.cfg.batch_size
        for s in range(0, len(todo), bs):
            idxs = todo[s:s + bs]
            pad = bs - len(idxs)
            if pad and self._routed:
                # pad with the last real query (not zeros): pad rows then
                # route like an existing query instead of activating
                # whatever shards the zero vector happens to map to
                pq, pf = queries[idxs[-1:]], filters[idxs[-1:]]
                q = np.concatenate([queries[idxs], np.repeat(pq, pad, 0)])
                f = np.concatenate([filters[idxs], np.repeat(pf, pad, 0)])
            else:
                q = np.concatenate(
                    [queries[idxs],
                     np.zeros((pad, queries.shape[1]), np.float32)])
                f = np.concatenate(
                    [filters[idxs],
                     np.zeros((pad, filters.shape[1]), np.float32)])
            qj, fj = jnp.asarray(q), jnp.asarray(f)
            scores, ids, covered = self._dispatch_batch(
                qj, fj, k, n_real=len(idxs), alive=alive)
            self.stats.bytes_scanned += self._batch_scan_bytes(bs)
            self.stats.scan_batches += 1
            scores, ids = np.asarray(scores), np.asarray(ids)
            for j, i in enumerate(idxs):
                out_scores[i], out_ids[i] = scores[j], ids[j]
                if covered is not None:
                    coverage[i] = covered[j]
                if use_cache:
                    self._cache_put(keys[i], (scores[j], ids[j]))

        self.stats.queries += n
        self.stats.uncovered_queries += int((~coverage).sum())
        self.stats.last_coverage = coverage
        self.stats.total_time_s += time.perf_counter() - t0
        return out_scores, out_ids

    # -- predicate-filtered search (filter algebra + planner) --------------
    def _search_filtered(self, queries, pred: Predicate,
                         plan: Optional[str] = None):
        """Exact predicate-filtered top-k (see ``search`` docstring).

        The predicate compiles once per call to fixed-shape arrays
        (``repro.core.filters.compile_predicate``); eligibility is evaluated
        host-side over the RAW attribute table and enters the jitted steps
        as a DATA operand, so plan identity + k + the pow-2 batch bucket are
        the only trace keys. All plans score against the SAME fold-
        transformed queries and funnel into the same canonical d2 + lexsort
        + finalize, so forced plans and topologies agree bit-for-bit.
        Pending delta rows are predicate-checked against the filters they
        were inserted with (when a custom ``attributes`` table was supplied,
        inserts must pass filters in that same attribute space)."""
        if self.planner is None:
            raise ValueError(
                "predicate-filtered search needs a flat or ivf backend "
                f"(index backend is {self.index.config.backend!r})")
        t0 = time.perf_counter()
        q = np.asarray(queries, np.float32)
        if q.ndim != 2 or q.shape[0] == 0:
            raise ValueError(
                f"queries must be a non-empty (n, d) batch; got shape "
                f"{np.shape(queries)}")
        d = self.index.transform.vec_norm.mean.shape[-1]
        if q.shape[1] != d:
            raise ValueError(
                f"query dimension mismatch: got {q.shape[1]}, index expects "
                f"{d}")
        if not np.isfinite(q).all():
            raise ValueError("queries contain NaN/Inf values")
        n, k = q.shape[0], self.cfg.k
        cp = compile_predicate(pred, self._attr_names)
        chosen = plan if plan is not None else self.planner.choose(cp)
        if plan is not None:
            if plan not in PLANS:
                raise ValueError(f"unknown plan {plan!r}; expected one of "
                                 f"{PLANS}")
            if plan == PLAN_FOLD and not self.planner.fold_capable(cp):
                raise ValueError(
                    "plan='fold' needs a flat fp32 backend and a single-"
                    "attribute predicate")
            if plan == PLAN_ROUTED and not self.planner.routed_capable():
                raise ValueError(
                    "plan='routed' needs an IVF backend or a sharded mesh")
        self.stats.queries += n
        self.stats.filtered_queries += n
        setattr(self.stats, f"plan_{chosen}",
                getattr(self.stats, f"plan_{chosen}") + n)
        self.stats.last_coverage = np.ones((n,), bool)

        elig_np = cp.eval_np(self._attrs_np)
        delta = self._ensure_delta()
        delig_np = None
        if delta is not None:
            delig_np = cp.eval_np(
                np.concatenate(self._delta_f).astype(np.float32))
        n_elig = int(elig_np.sum())
        nd_elig = 0 if delig_np is None else int(delig_np.sum())
        out_scores = np.full((n, k), -np.inf, np.float32)
        out_ids = np.full((n, k), -1, np.int64)
        if n_elig + nd_elig == 0:
            # zero-match predicate: certified-empty results, not padded
            # id-0 garbage (coverage stays 1.0 — the answer IS empty)
            self.stats.total_time_s += time.perf_counter() - t0
            return out_scores, out_ids

        # every plan scores against the SAME folded queries, computed once:
        # psi folds the predicate's representative RAW filter vector into
        # the query transform (the paper's filter fold)
        fold_raw = cp.fold_target_raw(self._col_means)
        q_t_all = fcvi.fold_queries(self.index, jnp.asarray(q), fold_raw)
        elig_j = jnp.asarray(elig_np)
        delig_j = None if delig_np is None else jnp.asarray(delig_np)

        main_dead = n_elig == 0
        uniq = n_live = None
        if (chosen == PLAN_ROUTED and self._sharded is None
                and not main_dead):
            r = ivf_mod.eligible_lists(np.asarray(self.index.backend.lists),
                                       elig_np)
            assert r is not None  # n_elig > 0 => at least one live list
            uniq, n_live = jnp.asarray(r[0]), jnp.asarray(r[1])
        kp = self.planner.kp_for(chosen, cp, k)
        if self.index.config.backend == "flat":
            kp = min(kp, self.index.size)  # top-k width can't exceed the scan

        bs = self.cfg.batch_size
        for s in range(0, n, bs):
            idxs = np.arange(s, min(s + bs, n))
            nb = min(bs, _pow2_at_least(len(idxs)))
            sel = np.full((nb,), idxs[-1], np.int64)
            sel[: len(idxs)] = idxs
            q_t = q_t_all[jnp.asarray(sel)]
            d2, ids = self._filtered_main(chosen, cp, q_t, elig_j,
                                          uniq, n_live, k=k, kp=kp,
                                          main_dead=main_dead)
            if delta is not None and nd_elig > 0:
                dd2, dids = _filtered_delta_step(delta.flat, q_t, delig_j,
                                                 k=k)
                dids = jnp.where(dids == flat_mod.DEAD_ID, flat_mod.DEAD_ID,
                                 dids + self.index.size)
                d2, ids = flat_mod.lexsort_topk(
                    jnp.concatenate([d2, dd2], axis=-1),
                    jnp.concatenate([ids, dids], axis=-1), k)
            scores, ids = flat_mod.finalize_filtered(d2, ids)
            out_scores[idxs] = np.asarray(scores)[: len(idxs)]
            out_ids[idxs] = np.asarray(ids, np.int64)[: len(idxs)]
            self.stats.scan_batches += 1

        self.stats.total_time_s += time.perf_counter() - t0
        return out_scores, out_ids

    def _filtered_main(self, plan: str, cp, q_t, elig_j, uniq, n_live, *,
                       k: int, kp: int, main_dead: bool):
        """Main-tier (d2, ids) for one padded batch under ``plan``.

        Pre-finalize convention: dead slots are (+inf, DEAD_ID) so the delta
        tier merges in d2-space. Sharded engines run mask/routed through the
        shard_map filtered step; the fold plan is always meshless (its
        certificate needs the global unmasked scan) — documented trade-off,
        the planner only picks it for flat fp32 where the meshless scan is
        cheap."""
        b = q_t.shape[0]
        if main_dead:
            return (jnp.full((b, k), jnp.inf, jnp.float32),
                    jnp.full((b, k), flat_mod.DEAD_ID, jnp.int32))
        if self._sharded is not None and plan in (PLAN_MASK, PLAN_ROUTED):
            lo, hi, iv, ic = cp.as_arrays()
            return self._sharded.filtered_step(
                q_t, lo, hi, iv, ic, k=k, routed=(plan == PLAN_ROUTED))
        backend = self.index.backend
        up = self.index.config.use_pallas
        if plan == PLAN_FOLD:
            d2, ids, cert = _filtered_fold_step(backend, q_t, elig_j,
                                                k=k, kp=kp, use_pallas=up)
            need = ~np.asarray(cert)
            if need.any():
                # uncertified rows re-run under the exhaustive mask plan in
                # a pow-2 sub-batch (same pattern as _dense_subbatch)
                fidx = np.nonzero(need)[0]
                self.stats.filtered_fallbacks += len(fidx)
                nb = b
                while nb // 2 >= max(len(fidx), 1):
                    nb //= 2
                sel = np.zeros((nb,), np.int64)
                sel[: len(fidx)] = fidx
                kpf = min(k + CANDIDATE_PAD, self.index.size)
                d2f, idsf = _filtered_mask_step(
                    backend, q_t[jnp.asarray(sel)], elig_j,
                    k=k, kp=kpf, use_pallas=up)
                take = jnp.asarray(fidx)
                d2 = d2.at[take].set(d2f[: len(fidx)])
                ids = ids.at[take].set(idsf[: len(fidx)])
            return d2, ids
        if plan == PLAN_MASK:
            return _filtered_mask_step(backend, q_t, elig_j, k=k, kp=kp,
                                       use_pallas=up)
        return _filtered_routed_step(backend, q_t, elig_j, uniq, n_live,
                                     k=k, kp=kp, use_pallas=up)

    def _dispatch_batch(self, q, f, k, n_real: int, alive):
        """One padded batch through the resilience envelope: bounded retry
        with exponential backoff on ``TransientShardError`` (raised by real
        dispatch failures or an attached fault injector), a per-batch
        deadline counter, and the heartbeat feed to the health layer."""
        attempt = 0
        while True:
            t0 = time.perf_counter()
            try:
                if self.fault_injector is not None:
                    self.fault_injector.before_batch()
                out = self._run_batch(q, f, k, n_real=n_real, alive=alive)
            except TransientShardError:
                attempt += 1
                self.stats.retries += 1
                if attempt > self.cfg.max_retries:
                    raise
                time.sleep(self.cfg.retry_backoff_s * (2 ** (attempt - 1)))
                continue
            elapsed = time.perf_counter() - t0
            if self.cfg.deadline_s and elapsed > self.cfg.deadline_s:
                self.stats.deadline_misses += 1
            if self.health is not None:
                if self.fault_injector is not None:
                    times = self.fault_injector.shard_times(
                        self.health.n_shards, elapsed)
                else:
                    # one shard_map dispatch: per-shard timing is not
                    # observable in-process, feed the batch wall time
                    times = [elapsed] * self.health.n_shards
                evicted = self.health.record_batch(times)
                self.stats.straggler_evictions += len(evicted)
            if alive is not None:
                self.stats.degraded_batches += 1
            return out

    def _run_batch(self, q, f, k, n_real: Optional[int] = None, alive=None):
        """One padded batch through the jitted step; escalation decided here
        (host-side bookkeeping), each stage a single compiled dispatch.

        Routed engines run the routed shard_map step first and re-run any
        query whose clipping flag is set through the DENSE step (same k'), so
        routed results always equal dense results end to end; the route mask
        feeds the off-trace router stats. Stage-2 escalation (and the routed
        fallback) runs ONLY the selected queries, gathered into a padded
        power-of-two sub-batch (so trace shapes stay bounded: one cached
        trace per bucket size) and scattered back — with the typical few-
        percent rates this is nearly free instead of re-running the whole
        batch. ``n_real`` caps both to the real rows of a padded batch:
        filler rows have data-dependent margins/flags and must not trigger
        (or count as) re-runs.

        ``alive`` (non-None = degraded mode) flows through EVERY stage —
        the routed step, the dense fallback, and the escalation sub-batch —
        so no stage can resurrect a dead shard's rows. Returns
        (scores, ids, covered): ``covered`` is the per-query coverage flag
        array capped to the real rows (None while healthy).
        """
        cfg = self.index.config
        degraded = alive is not None
        alpha = cfg.resolved_alpha()
        kp = theory.k_prime(k, cfg.lam, alpha, self.index.size, cfg.c)
        delta = self._ensure_delta()
        dvn = dfn = dflat = None
        kd = 0
        if delta is not None:
            nd = delta.vn.shape[0]
            kdp = theory.k_prime(k, cfg.lam, alpha, nd, cfg.c)
            kd = min(nd, max(kdp, 4 * k))
            dvn, dfn, dflat = delta.vn, delta.fn, delta.flat
        nr = q.shape[0] if n_real is None else n_real
        unc = None
        if self._routed:
            out = self._sharded.step(
                self._sharded_delta_view(dflat), q, f,
                k=k, kp=kp, kd=kd, routed=True, alive=alive,
                gather_free=self.cfg.gather_free)
            if degraded:
                scores, ids, margin, flag, rmask, unc = out
                unc = np.array(unc)
            else:
                scores, ids, margin, flag, rmask = out
            rm = np.asarray(rmask)
            self.stats.routed_batches += 1
            self.stats.shard_steps += rm.shape[1]
            self.stats.shards_active += int(rm.any(axis=0).sum())
            need = np.asarray(flag)[:nr]
            if need.any():
                idxs = np.nonzero(need)[0]
                self.stats.router_fallbacks += len(idxs)
                sub = self._dense_subbatch(dvn, dfn, dflat, q, f, idxs,
                                           k=k, kp=kp, kd=kd, alive=alive)
                s2, i2, m2 = sub[:3]
                take = jnp.asarray(idxs)
                scores = scores.at[take].set(s2)
                ids = ids.at[take].set(i2)
                margin = margin.at[take].set(m2)
                if degraded:
                    # the dense re-run's certificate (vs the dense k'-th
                    # candidate) supersedes the routed one for these rows
                    unc[idxs] = np.asarray(sub[3])
        else:
            out = self._step(dvn, dfn, dflat, q, f, k=k, kp=kp, kd=kd,
                             alive=alive)
            if degraded:
                scores, ids, margin, unc = out
                unc = np.array(unc)
            else:
                scores, ids, margin = out
        need = np.asarray(margin < self.cfg.escalate_margin)
        if n_real is not None:
            need = need[:n_real]
        if need.any():
            idxs = np.nonzero(need)[0]
            self.stats.escalations += len(idxs)
            kp2 = theory.k_prime(k, cfg.lam, alpha, self.index.size,
                                 cfg.c * self.cfg.kprime_escalation)
            sub = self._dense_subbatch(dvn, dfn, dflat, q, f, idxs,
                                       k=k, kp=kp2, kd=kd, alive=alive)
            s2, i2 = sub[:2]
            take = jnp.asarray(idxs)
            scores = scores.at[take].set(s2)
            ids = ids.at[take].set(i2)
            if degraded:
                unc[idxs] = np.asarray(sub[3])
        covered = None if unc is None else ~unc[:nr]
        return scores, ids, covered

    def _dense_subbatch(self, dvn, dfn, dflat, q, f, idxs, *,
                        k: int, kp: int, kd: int, alive=None):
        """Re-run ``idxs`` (row indices into the padded batch) through the
        dense step in a padded power-of-two sub-batch; pad slots recompute
        query 0. Returns the step's output rows for ``idxs`` (3 outputs, 4
        with a degraded ``alive`` mask)."""
        nb = q.shape[0]
        while nb // 2 >= max(len(idxs), 1):
            nb //= 2
        sel = np.zeros((nb,), np.int64)
        sel[: len(idxs)] = idxs
        sel_j = jnp.asarray(sel)
        out = self._step(dvn, dfn, dflat, q[sel_j], f[sel_j],
                         k=k, kp=kp, kd=kd, alive=alive)
        n = len(idxs)
        return tuple(o[:n] for o in out)

    def _sharded_delta_view(self, dflat):
        """Lazily (re)shard the delta buffer for the shard_map steps."""
        if dflat is None:
            return None
        if self._sharded_delta is None:
            self._sharded_delta = self._sharded.shard_delta(self._delta)
        return self._sharded_delta

    def _rows_payload(self):
        """IVF gather-free payload slabs (lazy): the re-rank originals
        ``vectors_n``/``filters_n`` regrouped into (nlist, max_list, dim)
        list order, so the dedup rows-kernel can emit the winners' re-rank
        rows straight from its scan. Flat needs no extra payload — corpus
        order IS slab order — so it returns (None, None). Invalidated on
        ``compact()``/``heal()`` (the only events that change the corpus)."""
        if self.index.config.backend != "ivf":
            return None, None
        if self._grouped_payload is None:
            from repro.index import ivf as ivf_mod
            lists = self.index.backend.lists
            self._grouped_payload = (
                ivf_mod.build_grouped_payload(self.index.vectors_n, lists),
                ivf_mod.build_grouped_payload(self.index.filters_n, lists))
        return self._grouped_payload

    def _step(self, dvn, dfn, dflat, q, f, *, k: int, kp: int, kd: int,
              alive=None):
        """Dispatch one padded batch to the single-device jitted step or the
        mesh-sharded DENSE shard_map step (identical results by
        construction; the routed step is dispatched by ``_run_batch``).
        ``cfg.gather_free`` picks the rows-carrying step variant for the
        flat/IVF backends (PQ re-ranks from reconstructed originals and
        keeps the id-gather step)."""
        if self._sharded is None:
            if (self.cfg.gather_free
                    and self.index.config.backend in ("flat", "ivf")):
                gpv, gpf = self._rows_payload()
                return _batch_step_rows(self.index, dvn, dfn, dflat,
                                        gpv, gpf, q, f, k=k, kp=kp, kd=kd)
            return _batch_step(self.index, dvn, dfn, dflat, q, f,
                               k=k, kp=kp, kd=kd)
        return self._sharded.step(self._sharded_delta_view(dflat), q, f,
                                  k=k, kp=kp, kd=kd, alive=alive,
                                  gather_free=self.cfg.gather_free)

    def _staged_query(self, q, f, k):
        """Pre-jit two-stage query WITHOUT the delta merge — kept as the
        faithful legacy baseline for benchmarks/query_path.py."""
        scores, ids = fcvi.query(self.index, q, f, k)
        margin = scores[:, 0] - scores[:, -1]
        need = np.asarray(margin < self.cfg.escalate_margin)
        if need.any():
            self.stats.escalations += int(need.sum())
            cfg = self.index.config
            kp2 = theory.k_prime(k, cfg.lam, cfg.resolved_alpha(),
                                 self.index.size,
                                 cfg.c * self.cfg.kprime_escalation)
            s2, i2 = fcvi.query(self.index, q, f, k, k_prime=kp2)
            sel = jnp.asarray(need)[:, None]
            scores = jnp.where(sel, s2, scores)
            ids = jnp.where(sel, i2, ids)
        return scores, ids

    def search_predicate(self, queries: np.ndarray, pred: BoxPredicate):
        """Range/disjunctive predicate -> multi-probe (§4.3)."""
        probes = np.asarray(pred.probes(self.cfg.multi_probe_r))  # (r, m)
        n = queries.shape[0]
        fp = jnp.broadcast_to(jnp.asarray(probes)[None],
                              (n, *probes.shape))
        return fcvi.multi_probe_query(self.index, jnp.asarray(queries), fp,
                                      self.cfg.k)

    # -- updates ----------------------------------------------------------
    def insert(self, vectors: np.ndarray, filters: np.ndarray):
        self._delta_v.append(np.asarray(vectors, np.float32))
        self._delta_f.append(np.asarray(filters, np.float32))
        self.stats.inserts += len(vectors)
        self._cache.clear()  # results may change
        self._delta = None   # invalidate; rebuilt lazily on the next search
        self._sharded_delta = None
        if sum(len(v) for v in self._delta_v) >= self.cfg.compact_threshold:
            self.compact()

    def delta_size(self) -> int:
        return sum(len(v) for v in self._delta_v)

    def _ensure_delta(self) -> Optional[_DeltaBuffer]:
        """Materialise the device-resident delta buffer on first use after an
        insert (lazy, so back-to-back inserts cost nothing until a query)."""
        if self._delta is None and self._delta_v:
            cfg = self.index.config
            tfm = self.index.transform
            vn = tfm.vec_norm.apply(jnp.asarray(np.concatenate(self._delta_v)))
            fn = tfm.filt_norm.apply(jnp.asarray(np.concatenate(self._delta_f)))
            self._delta = _DeltaBuffer(
                vn=vn, fn=fn,
                flat=flat_mod.build(tfm.apply_normalized(vn, fn),
                                    storage_dtype=cfg.resolved_storage_dtype()))
        return self._delta

    def compact(self):
        if not self._delta_v:
            return
        v = np.concatenate(self._delta_v)
        f = np.concatenate(self._delta_f)
        self.index = fcvi.extend(self.index, jnp.asarray(v), jnp.asarray(f))
        # the compacted rows' attribute values are the filters they were
        # inserted with; refresh the planner's selectivity histograms
        self._attrs_np = np.concatenate([self._attrs_np, f])
        self._col_means = self._attrs_np.mean(axis=0).astype(np.float32)
        self._rebuild_planner()
        self._delta_v, self._delta_f = [], []
        self._delta = None
        self._sharded_delta = None
        self._grouped_payload = None  # corpus changed: payload slabs stale
        self._router_centers = None  # corpus changed: re-derive the router
        if self._sharded is not None:
            self._build_sharded()   # re-shard the grown slabs onto the mesh
        self.stats.compactions += 1

    # -- self-healing ------------------------------------------------------
    def heal(self, ckpt_dir: str, probe_queries=None, probe_filters=None,
             *, step: int = 0, background: bool = False):
        """Recover full coverage after shard loss via elastic re-place.

        checkpoint -> restore the FULL corpus onto a mesh of only the
        surviving devices (placement/routing preserved, so affinity packing
        is re-derived from the same router geometry) -> validate the
        candidate engine bit-identically against a meshless restore of the
        same checkpoint on ``probe_queries``/``probe_filters`` -> cut over
        under the heal lock (swap index/mesh/sharded state, fresh health
        layer, cache cleared). After a successful heal every row is served
        again and coverage returns to 100%.

        Returns True on a validated cutover, False when validation failed
        (the degraded engine keeps serving untouched). ``background=True``
        runs the same flow on a daemon thread and returns it (join it, then
        check ``stats.heals``). Requires a mesh-backed engine with one
        device per shard and at least one surviving device.
        """
        if background:
            t = threading.Thread(
                target=self.heal, args=(ckpt_dir, probe_queries,
                                        probe_filters),
                kwargs={"step": step}, daemon=True)
            t.start()
            return t
        if self._sharded is None or self.health is None:
            raise RuntimeError("heal() requires a mesh-backed engine")
        devices = np.asarray(self._mesh.devices).reshape(-1)
        if self._sharded.n_shards != devices.size:
            raise NotImplementedError(
                "heal() assumes one shard per mesh device")
        alive_idx = np.nonzero(self.health.alive_mask())[0]
        if alive_idx.size == 0:
            raise RuntimeError("heal() needs at least one surviving shard")
        self.save(ckpt_dir, step=step)
        from jax.sharding import Mesh

        shape = (alive_idx.size,) + (1,) * (len(self._mesh.axis_names) - 1)
        new_mesh = Mesh(devices[alive_idx].reshape(shape),
                        self._mesh.axis_names)
        cand = FCVIEngine.restore(ckpt_dir, step=step, config=self.cfg,
                                  mesh=new_mesh, rules=self._rules,
                                  placement=self._placement,
                                  routing=self._routing)
        if probe_queries is not None:
            ref = FCVIEngine.restore(ckpt_dir, step=step, config=self.cfg)
            s_new, i_new = cand.search(probe_queries, probe_filters)
            s_ref, i_ref = ref.search(probe_queries, probe_filters)
            if not (np.array_equal(s_new, s_ref)
                    and np.array_equal(i_new, i_ref)):
                return False
        with self._heal_lock:
            self.index = cand.index
            self._mesh = new_mesh
            self._attrs_np = cand._attrs_np
            self._attr_names = cand._attr_names
            self._col_means = cand._col_means
            self.planner = cand.planner
            self._router_centers = cand._router_centers
            self._sharded = cand._sharded
            self._sharded_delta = cand._sharded_delta
            self._delta_v = cand._delta_v
            self._delta_f = cand._delta_f
            self._delta = cand._delta
            self._grouped_payload = None
            self.health = ShardHealth(self._sharded.n_shards,
                                      straggler_z=self.cfg.straggler_z)
            self._alive_sig = None
            self._cache.clear()
            self.stats.heals += 1
        return True

    # -- checkpoint lifecycle ---------------------------------------------
    def save(self, ckpt_dir: str, step: int = 0, keep: int = 3) -> str:
        """Checkpoint the full serving state (build -> checkpoint -> restore
        -> serve lifecycle).

        Saves the transform + backend source arrays + re-rank originals via
        ``fcvi.index_state`` (derived serving slabs are rebuilt at restore
        time by the slab layer) plus any PENDING delta rows, with the static
        configs — including the serving placement/routing knobs — in the
        manifest metadata. Cluster-placed flat engines also save the router's
        psi-cluster centers ((ncl, d) fp32) so a restored engine derives the
        SAME routing tables (labels, radii, shard incidence) on any target
        mesh instead of re-running k-means. Sharded arrays are gathered to
        host transparently by the checkpoint writer.
        """
        d = self.index.transform.vec_norm.mean.shape[-1]
        m = self.index.transform.filt_norm.mean.shape[-1]
        dv = (np.concatenate(self._delta_v) if self._delta_v
              else np.zeros((0, d), np.float32))
        df = (np.concatenate(self._delta_f) if self._delta_f
              else np.zeros((0, m), np.float32))
        tree = {"index": fcvi.index_state(self.index),
                "delta_v": dv, "delta_f": df,
                "attrs": self._attrs_np}
        if (self._sharded is not None
                and getattr(self._sharded.slab, "router_centers", None)
                is not None):
            tree["router"] = {
                "centers": np.asarray(self._sharded.slab.router_centers)}
        metadata = {
            "fcvi_config": dataclasses.asdict(self.index.config),
            "engine_config": dataclasses.asdict(self.cfg),
            "serving": {"placement": self._placement,
                        "routing": self._routing,
                        "attr_names": list(self._attr_names)},
        }
        return ckpt_mod.save(ckpt_dir, step, tree, metadata=metadata,
                             keep=keep)

    @classmethod
    def restore(cls, ckpt_dir: str, *, step: Optional[int] = None,
                config: Optional[EngineConfig] = None, mesh=None, rules=None,
                placement: Optional[str] = None,
                routing: Optional[str] = None) -> "FCVIEngine":
        """Restore an engine from a checkpoint onto ANY target mesh.

        The elastic-restart path: arrays come back replicated on host, the
        index is rebuilt without re-training (k-means state is part of the
        checkpoint), and — when ``mesh`` is given — the slab layer re-lays
        the serving state out over the TARGET mesh, which may have a
        different shape than the mesh the checkpoint was written from.
        ``placement``/``routing`` default to the values the engine was saved
        with (pass explicitly to override); saved router centers are reused,
        so a routed engine restored onto any mesh routes from the same
        psi-cluster geometry it served with. ``mesh=None`` always serves the
        single-device step (routing needs shards to skip).
        """
        tree, _, metadata = ckpt_mod.load(ckpt_dir, step=step)
        fcfg = FCVIConfig(**metadata["fcvi_config"])
        index = fcvi.index_from_state(fcfg, tree["index"])
        ecfg = (config if config is not None
                else EngineConfig(**metadata["engine_config"]))
        serving = metadata.get("serving", {})
        if placement is None:
            placement = serving.get("placement", "contiguous")
        if routing is None:
            routing = serving.get("routing", "dense")
        if mesh is None:
            routing = "dense"
        centers = None
        if "router" in tree:
            centers = jnp.asarray(tree["router"]["centers"], jnp.float32)
        eng = cls(index, ecfg, mesh=mesh, rules=rules, placement=placement,
                  routing=routing, router_centers=centers,
                  attributes=tree.get("attrs"),
                  attr_names=serving.get("attr_names"))
        if tree["delta_v"].shape[0]:
            eng._delta_v = [np.asarray(tree["delta_v"], np.float32)]
            eng._delta_f = [np.asarray(tree["delta_f"], np.float32)]
            eng.stats.inserts = int(tree["delta_v"].shape[0])
        return eng
