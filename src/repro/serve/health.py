"""Shard-health layer: ``distributed.fault`` policies wired to serving.

``ShardHealth`` tracks the liveness of the serving shards of ONE
``ShardedServing`` mesh by reusing the training-side ``HeartbeatTracker``
(EWMA z-score straggler detection with patience, step-timeout failure
detection) at shard granularity: one "host" per shard, one "step" per
dispatched engine batch. The engine feeds it per-batch per-shard step times
(real, or synthetic from the fault-injection harness — on a forced host mesh
all shards share cores, so per-shard timing is only observable via
injection) and consults ``alive_mask()`` before every search:

  * a shard marked dead (operator ``mark_dead``, heartbeat timeout via
    ``check_failures``, or straggler eviction inside ``record_batch``) is
    masked out of the sharded batch step — it takes the existing
    ``lax.cond`` zero-work branch, exactly as if no query ever routed to it
    (dead == never-routed);
  * the engine then serves DEGRADED: results are bit-identical to a search
    restricted to the surviving shards' rows, and queries whose certificate
    shows the dead shards could have held a top-k hit carry a per-query
    coverage flag (``EngineStats.last_coverage``) instead of silently wrong
    results;
  * ``FCVIEngine.heal`` turns the elastic checkpoint/restore path into
    recovery: checkpoint, re-place the full corpus onto the surviving mesh
    (placement preserved), validate the new engine with the bit-identity
    harness, cut over, and reset health.

The exception types of the off-trace resilience envelope live here too:
``TransientShardError`` is what the engine's bounded-retry loop catches
(raised by real dispatch failures or the fault injector), and
``BackpressureError`` is raised when the cache-miss dispatch queue exceeds
``EngineConfig.queue_budget`` — the caller sheds load instead of queueing
unboundedly.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.distributed import fault


class TransientShardError(RuntimeError):
    """A per-batch shard dispatch failure worth retrying (with backoff)."""


class BackpressureError(RuntimeError):
    """The dispatch queue exceeded the engine's queue budget; shed load."""


class ShardHealth:
    """Liveness + straggler tracking for the shards of one serving mesh."""

    def __init__(self, n_shards: int, *, alpha: float = 0.2,
                 straggler_z: float = 3.0, straggler_patience: int = 3,
                 timeout_steps: int = 2, evict_stragglers: bool = True):
        self.n_shards = n_shards
        self.tracker = fault.HeartbeatTracker(
            n_hosts=n_shards, alpha=alpha, straggler_z=straggler_z,
            straggler_patience=straggler_patience,
            timeout_steps=timeout_steps)
        self.evict_stragglers = evict_stragglers
        self._batch = 0          # monotone batch counter == heartbeat step

    # -- heartbeat feed ----------------------------------------------------
    def record_batch(self, shard_times: Sequence[float]) -> list:
        """Record one dispatched batch's per-shard step times.

        Dead shards are skipped (they produced no heartbeat). Persistent
        stragglers — shards z-sigma slower than the fleet for
        ``straggler_patience`` consecutive batches — are evicted like
        failures (marked dead, masked from the next batch on) when
        ``evict_stragglers`` is set; the evicted shard ids are returned so
        the engine can count them.
        """
        step = self._batch
        self._batch += 1
        for s, t in enumerate(shard_times):
            if s < self.n_shards and self.tracker.hosts[s].alive:
                self.tracker.record(s, step, float(t))
        if not self.evict_stragglers:
            return []
        evicted = [s for s in self.tracker.stragglers()
                   if self.tracker.hosts[s].alive]
        if evicted:
            self.tracker.mark_dead(evicted)
        return evicted

    def check_failures(self) -> list:
        """Mark (and return) shards silent past the heartbeat timeout."""
        dead = self.tracker.failures(self._batch)
        if dead:
            self.tracker.mark_dead(dead)
        return dead

    # -- liveness ----------------------------------------------------------
    def mark_dead(self, shards: Sequence[int]):
        self.tracker.mark_dead(list(shards))

    def mark_alive(self, shards: Sequence[int]):
        self.tracker.mark_alive(list(shards))

    def alive_mask(self) -> np.ndarray:
        """(n_shards,) bool — True for shards still serving."""
        mask = np.zeros((self.n_shards,), bool)
        mask[self.tracker.alive_hosts()] = True
        return mask

    def dead_shards(self) -> list:
        return [s for s in range(self.n_shards)
                if not self.tracker.hosts[s].alive]

    def any_dead(self) -> bool:
        return len(self.tracker.alive_hosts()) < self.n_shards

    def n_alive(self) -> int:
        return len(self.tracker.alive_hosts())
