"""Selectivity-aware query planner for the filter algebra.

Every compiled predicate executes under one of three PHYSICAL PLANS, all of
which feed the same exact filtered refine (so results are bit-identical —
the planner is a pure performance decision):

  * ``fold``   — psi fold, as the paper runs single-attribute filters: the
    predicate's representative filter vector folds into the query transform,
    candidates come from the UNMASKED scan (the fold geometry pulls matching
    rows to the top), and a per-query certificate (enough eligible rows in
    the candidate set) guards exactness, falling back to ``mask`` when it
    fails. Right for BROAD single-attribute predicates, where most scanned
    rows are eligible anyway.
  * ``mask``   — in-kernel candidate masking: the eligibility mask rides
    into ``ops.score_topk`` / ``ops.ivf_score_topk_dedup`` as an operand and
    ineligible rows score -inf inside the scan. Exhaustive over eligible
    rows — exact for ANY predicate, the safe default at mid selectivity.
  * ``routed`` — pruning: only the inverted lists (meshless IVF) / shards
    (sharded serving, via the zero-work ``lax.cond`` branch) that hold at
    least one eligible row are scanned, with the in-scan mask finishing the
    job. Right for SELECTIVE predicates, where most of the corpus never
    needs to be touched.

The choice comes from cheap per-attribute equi-width histograms maintained
on the index (plus exact value counts for low-cardinality categorical
columns), combined under the attribute-independence assumption — the
Compass / filtered-PostgreSQL framing of pre-/post-/in-filter routing as a
per-query cost decision. Estimates only steer the plan choice; correctness
never depends on them.

Jit-key discipline: the plan name (and the static candidate width it
implies) IS the jit key — predicate bounds, IN-lists, masks, and routed
list ids are all data operands — so steady-state serving traces each
(plan, k) pair once no matter how predicates vary.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.filters import CompiledPredicate

PLAN_FOLD = "fold"
PLAN_MASK = "mask"
PLAN_ROUTED = "routed"
PLANS = (PLAN_FOLD, PLAN_MASK, PLAN_ROUTED)

#: Columns with at most this many distinct values keep exact value counts
#: (categorical estimation); everything else uses the histogram.
MAX_VALUE_COUNTS = 64

#: Exact-refine headroom on the mask/routed candidate sets (matches the
#: index layer's REFINE_PAD: absorbs scan-vs-refine ULP reorderings).
CANDIDATE_PAD = 8


def _pow2_at_least(x: int) -> int:
    return 1 << max(0, int(x - 1).bit_length())


@dataclasses.dataclass
class ColumnStats:
    """Per-attribute selectivity statistics: an equi-width histogram plus
    exact value counts when the column is low-cardinality categorical."""

    edges: np.ndarray          # (bins+1,) histogram bin edges
    counts: np.ndarray         # (bins,) rows per bin
    n: int
    value_counts: Optional[Dict[float, int]]  # exact, when distinct is small

    @classmethod
    def build(cls, col: np.ndarray, bins: int = 64) -> "ColumnStats":
        col = np.asarray(col, np.float32)
        n = int(col.shape[0])
        uniq, ucounts = np.unique(col, return_counts=True)
        vc = None
        if uniq.shape[0] <= MAX_VALUE_COUNTS:
            vc = {float(v): int(c) for v, c in zip(uniq, ucounts)}
        lo = float(col.min()) if n else 0.0
        hi = float(col.max()) if n else 1.0
        if hi <= lo:
            hi = lo + 1.0
        counts, edges = np.histogram(col, bins=bins, range=(lo, hi))
        return cls(edges=edges.astype(np.float64),
                   counts=counts.astype(np.float64), n=n, value_counts=vc)

    def _cdf(self, x: float) -> float:
        """Estimated fraction of rows with value <= x (linear within bins)."""
        if self.n == 0:
            return 0.0
        e, c = self.edges, self.counts
        if x <= e[0]:
            return 0.0
        if x >= e[-1]:
            return 1.0
        j = int(np.searchsorted(e, x, side="right")) - 1
        j = min(max(j, 0), c.shape[0] - 1)
        width = e[j + 1] - e[j]
        frac = (x - e[j]) / width if width > 0 else 1.0
        return float((c[:j].sum() + c[j] * frac) / self.n)

    def sel_range(self, lo: float, hi: float) -> float:
        if hi < lo:
            return 0.0
        return max(0.0, min(1.0, self._cdf(hi) - self._cdf(lo)))

    def sel_values(self, values) -> float:
        if self.n == 0:
            return 0.0
        if self.value_counts is not None:
            hit = sum(self.value_counts.get(float(v), 0) for v in values)
            return min(1.0, hit / self.n)
        # histogram fallback: charge each value its bin's density
        sel = 0.0
        for v in values:
            j = int(np.searchsorted(self.edges, float(v), side="right")) - 1
            if 0 <= j < self.counts.shape[0]:
                sel += float(self.counts[j]) / self.n
        return min(1.0, sel)


@dataclasses.dataclass
class QueryPlanner:
    """Compiles a predicate's selectivity estimate into a physical plan.

    Capability flags pin which plans the current (backend, topology,
    storage) can run: ``routed`` needs prunable structure (IVF inverted
    lists, or a sharded mesh whose shards can ``lax.cond``-skip); ``fold``
    needs the flat fp32 scan (its certificate reads exact scan scores) and a
    single-attribute predicate (psi folds one representative vector).
    """

    columns: List[ColumnStats]
    n: int
    backend: str
    storage_fp32: bool
    sharded: bool
    routed_max_sel: float = 0.05
    fold_min_sel: float = 0.5

    @classmethod
    def build(cls, attrs: np.ndarray, *, backend: str, storage_fp32: bool,
              sharded: bool, bins: int = 64) -> "QueryPlanner":
        attrs = np.asarray(attrs, np.float32)
        cols = [ColumnStats.build(attrs[:, j], bins=bins)
                for j in range(attrs.shape[1])]
        return cls(columns=cols, n=int(attrs.shape[0]), backend=backend,
                   storage_fp32=storage_fp32, sharded=sharded)

    def selectivity(self, cp: CompiledPredicate) -> float:
        """Estimated matching fraction under attribute independence."""
        sel = 1.0
        for j in cp.constrained:
            st = self.columns[j]
            c = int(cp.isin_count[j])
            if c > 0:
                s = st.sel_values(cp.isin_vals[j, :c])
                # an IN-list combined with range bounds on the same column
                # keeps the tighter of the two estimates
                s = min(s, st.sel_range(float(cp.lo[j]), float(cp.hi[j])))
            else:
                s = st.sel_range(float(cp.lo[j]), float(cp.hi[j]))
            sel *= s
        return sel

    def routed_capable(self) -> bool:
        return self.backend == "ivf" or self.sharded

    def fold_capable(self, cp: CompiledPredicate) -> bool:
        return (self.backend == "flat" and self.storage_fp32
                and len(cp.constrained) == 1)

    def choose(self, cp: CompiledPredicate) -> str:
        sel = self.selectivity(cp)
        if sel <= self.routed_max_sel and self.routed_capable():
            return PLAN_ROUTED
        if sel >= self.fold_min_sel and self.fold_capable(cp):
            return PLAN_FOLD
        return PLAN_MASK

    def kp_for(self, plan: str, cp: CompiledPredicate, k: int) -> int:
        """Static candidate width per plan (pow-2 so the jit key ladder stays
        short). mask/routed scans are exhaustive over eligible rows, so a
        small refine pad suffices; the fold scan is unmasked, so it needs
        ~k/selectivity candidates for its certificate to usually hold."""
        if plan == PLAN_FOLD:
            sel = max(self.selectivity(cp), 1e-3)
            want = int(np.ceil(4.0 * k / sel))
            return min(self.n, _pow2_at_least(want)) if self.n else k
        return k + CANDIDATE_PAD
