"""Mesh-sharded serving: the engine batch step as a ``shard_map`` body.

``ShardedServing`` is the device-mesh counterpart of the single-device
``FCVIEngine`` hot path. The index's serving slab (``repro.index.slab``) is
sharded over the mesh — flat slabs by ROW, IVF slabs by LIST — together with
the normalized re-scoring originals and the engine's delta insert buffer, and
the whole per-batch computation runs as ONE jitted ``shard_map``:

  1. query transform: replicated (identical math on every shard; the fused
     Pallas kernel is preserved inside the shard when ``use_pallas`` is set),
  2. candidate generation: each shard scans only ITS slab block (kernel-backed
     when ``use_pallas``) and emits (score, global id) candidates,
  3. cross-shard merge: the per-axis tree top-k merge
     (``index.distributed.tree_merge_topk`` over ``flat.merge_topk``) reduces
     the per-shard sets to the exact global top-k' — only (k' x shards)
     candidate tuples ever cross the interconnect, never raw score matrices,
  4. re-ranking: candidate rows are fetched from the ROW-SHARDED normalized
     originals with a mask+psum distributed gather (each id is owned by
     exactly one shard; summing one real row with zeros is float-exact), then
     combined-scored exactly as the single-device path,
  5. delta merge: the per-shard delta buffer is searched locally, tree-merged,
     and folded in with the same shard-aware ``merge_topk``.

Parity contract: the sharded step returns results IDENTICAL to the
single-device ``engine._batch_step`` for any mesh shape (including 1 device)
— per-row arithmetic is unchanged, per-shard candidate sets provably contain
every global winner (a shard can hold at most k' of the global top-k', so
per-shard top-min(k', local) + exact tree merge loses nothing), and the
exact-refine / re-rank stages run on the same fp32 values.
``tests/test_sharded_engine.py`` enforces this on a forced 8-device host
mesh, kernels on and off.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import fcvi
from repro.index import flat as flat_mod
from repro.index import slab as slab_mod
from repro.index.distributed import tree_merge_topk
from repro.kernels import ops

Array = jax.Array


def _linear_shard_index(axes, sizes):
    """This device's linear shard index over the (row-major) product axes."""
    lin = jnp.int32(0)
    stride = 1
    for ax, n_ax in zip(reversed(tuple(axes)), reversed(tuple(sizes))):
        lin = lin + jax.lax.axis_index(ax) * stride
        stride = stride * n_ax
    return lin


def _gather_rows(local_rows: Array, gids: Array, lin, n_local: int, axes):
    """Distributed gather from a contiguously row-sharded array.

    ``local_rows`` is this shard's (n_local, ...) block of a (ns*n_local,
    ...) array; ``gids`` are global row ids (replicated). Each id is owned by
    exactly one shard: owners contribute the real row, everyone else zeros,
    and the psum reconstructs the gathered rows replicated. Adding zeros is
    float-exact, so the result is bit-identical to a local gather.
    """
    owner = gids // n_local
    mine = owner == lin
    loc = jnp.where(mine, gids % n_local, 0)
    part = jnp.where(mine[..., None], local_rows[loc], 0)
    for ax in axes:
        part = jax.lax.psum(part, ax)
    return part


def _local_flat_topk(vectors: Array, sq_norms: Array, row_ids: Array,
                     queries: Array, kl: int, use_pallas: bool):
    """Per-shard flat candidate generation with globally valid ids.

    Mirrors ``flat.search`` exactly (matmul-expansion candidate scores, then
    the fp32 exact-refine re-ordering), with padding rows (row_ids == -1,
    +inf squared norms) masked out of the refine so they can never outscore
    real rows.
    """
    nl = vectors.shape[0]
    kl = min(kl, nl)
    kk = min(nl, kl + flat_mod.REFINE_PAD)
    if use_pallas:
        _, cand = ops.score_topk_padded(vectors, sq_norms, queries, kk)
    else:
        q2 = jnp.sum(queries * queries, axis=-1, keepdims=True)
        scores = -(q2 - 2.0 * queries @ vectors.T + sq_norms[None, :])
        _, cand = jax.lax.top_k(scores, kk)
    vals, idx = flat_mod._exact_refine(vectors, queries, cand, kl,
                                       mask=row_ids >= 0)
    return vals, row_ids[idx]


@dataclasses.dataclass
class ShardedDelta:
    """Per-shard view of the engine's delta insert buffer (row-sharded)."""

    vt: Array       # (nd_pad, d) transformed delta rows, sharded
    sq: Array       # (nd_pad,) squared norms, +inf pads, sharded
    row_ids: Array  # (nd_pad,) delta-local ids, -1 pads, sharded
    vn: Array       # (nd_pad, d) normalized originals, sharded
    fn: Array       # (nd_pad, m) normalized filters, sharded
    nd: int         # real delta rows
    n_local: int    # rows per shard


class ShardedServing:
    """Sharded slabs + jitted shard_map steps for one (index, mesh) pair.

    Construction shards the serving state once (``slab.shard`` +
    row-sharding the re-rank originals); ``step`` lazily builds and caches
    one jitted shard_map per static (k, k', kd, delta-shape) signature —
    exactly mirroring the jit cache structure of the single-device
    ``_batch_step``.
    """

    def __init__(self, index, mesh, rules=None, *,
                 placement: str = "contiguous"):
        from repro.distributed.sharding import AxisRules

        self.index = index
        self.mesh = mesh
        self.rules = rules if rules is not None else AxisRules(mesh)
        self.placement = placement
        cfg = index.config
        if cfg.backend == "flat":
            self.slab = index.backend.slab().shard(
                mesh, self.rules, placement=placement)
        elif cfg.backend == "ivf":
            ivf_placement = "balanced" if placement == "cluster" else placement
            self.slab = index.backend.slab().shard(
                mesh, self.rules, placement=ivf_placement,
                list_sizes=index.backend.list_sizes)
        else:
            raise NotImplementedError(
                f"mesh-sharded serving supports the flat/ivf backends, not "
                f"{cfg.backend!r}")
        self.axes = self.slab.axes
        self.sizes = tuple(mesh.shape[a] for a in self.axes)
        self.n_shards = slab_mod.axes_size(mesh, self.axes)
        # normalized originals, contiguously row-sharded for the distributed
        # re-rank gather (independent of the slab's candidate placement)
        n = index.size
        self.rows_local = -(-n // max(self.n_shards, 1))
        n_pad = self.rows_local * self.n_shards
        self.vectors_n = self._put_rows(
            slab_mod.pad_dim0(index.vectors_n, n_pad, 0))
        self.filters_n = self._put_rows(
            slab_mod.pad_dim0(index.filters_n, n_pad, 0))
        self._steps = {}

    def _put_rows(self, x: Array) -> Array:
        return jax.device_put(x, NamedSharding(self.mesh, P(self.axes)))

    # -- delta ------------------------------------------------------------
    def shard_delta(self, delta) -> ShardedDelta:
        """Shard the engine's device-resident delta buffer over the mesh."""
        nd = delta.vn.shape[0]
        nl = -(-nd // self.n_shards)
        nd_pad = nl * self.n_shards
        ids = jnp.concatenate(
            [jnp.arange(nd, dtype=jnp.int32),
             jnp.full((nd_pad - nd,), -1, jnp.int32)])
        return ShardedDelta(
            vt=self._put_rows(
                slab_mod.pad_dim0(delta.flat.vectors, nd_pad, 0)),
            sq=self._put_rows(
                slab_mod.pad_dim0(delta.flat.sq_norms, nd_pad, jnp.inf)),
            row_ids=self._put_rows(ids),
            vn=self._put_rows(slab_mod.pad_dim0(delta.vn, nd_pad, 0)),
            fn=self._put_rows(slab_mod.pad_dim0(delta.fn, nd_pad, 0)),
            nd=nd, n_local=nl,
        )

    # -- the sharded batch step -------------------------------------------
    def step(self, delta: Optional[ShardedDelta], q: Array, f: Array, *,
             k: int, kp: int, kd: int):
        """One padded batch through the sharded hot path; same contract as
        ``engine._batch_step``: (scores (b, k), ids (b, k), margin (b,))."""
        nld = None if delta is None else delta.n_local
        key = (k, kp, kd, nld)
        fn = self._steps.get(key)
        if fn is None:
            fn = self._steps[key] = self._build_step(k, kp, kd, nld)
        slab_args = self._slab_args()
        if delta is None:
            return fn(self.index.transform, *slab_args, self.vectors_n,
                      self.filters_n, q, f)
        return fn(self.index.transform, *slab_args, self.vectors_n,
                  self.filters_n, delta.vt, delta.sq, delta.row_ids,
                  delta.vn, delta.fn, q, f)

    def _slab_args(self):
        s = self.slab
        if self.index.config.backend == "flat":
            return (s.vectors, s.sq_norms, s.row_ids)
        return (s.grouped, s.grouped_sq, s.valid, s.lists, s.centroids,
                s.c_sq, s.slot_of_list)

    def _slab_specs(self, row):
        if self.index.config.backend == "flat":
            return (row, row, row)
        # grouped layouts are list-sharded; centroid state is replicated
        return (row, row, row, row, P(), P(), P())

    def _build_step(self, k: int, kp: int, kd: int, nld: Optional[int]):
        from repro.serve import engine as engine_mod

        cfg = self.index.config
        axes, sizes = self.axes, self.sizes
        use_pallas = cfg.use_pallas
        backend = cfg.backend
        rows_local = self.rows_local
        index_size = self.index.size
        has_delta = nld is not None
        if backend == "flat":
            kl = min(kp, self.slab.n_local)
        else:
            nprobe = min(cfg.nprobe, self.slab.nlist)
            lpp = self.slab.lists_per_shard + 1
            max_list = self.slab.max_list
            kl_ivf = min(kp, nprobe * max_list)

        def flat_candidates(slab_args, q_t, lin):
            vectors, sq_norms, row_ids = slab_args
            return _local_flat_topk(vectors, sq_norms, row_ids, q_t, kl,
                                    use_pallas)

        def ivf_candidates(slab_args, q_t, lin):
            grouped, grouped_sq, valid, lists, c, c2, slot_of = slab_args
            q2 = jnp.sum(q_t * q_t, axis=-1, keepdims=True)
            # coarse quantizer: replicated, identical to the single-device
            # path (centroid scoring is just a tiny flat search)
            if use_pallas:
                _, probe = ops.score_topk_padded(c, c2, q_t, nprobe)
            else:
                cd = -(q2 - 2.0 * q_t @ c.T + c2[None, :])
                _, probe = jax.lax.top_k(cd, nprobe)
            slot = slot_of[probe]                          # (b, nprobe)
            mine = (slot // lpp) == lin
            # non-local probes go to this shard's all-invalid sentinel slot
            local = jnp.where(mine, slot % lpp, lpp - 1)
            if use_pallas:
                uniq, member = ops.dedup_probes(local.astype(jnp.int32), lpp)
                vals, flat_ids = ops.ivf_score_topk_dedup(
                    grouped, grouped_sq, valid, uniq, member, q_t, kl_ivf)
                cand = lists.reshape(-1)[flat_ids]         # -1 on pad slots
                return vals - q2, cand

            def one_query(qv, q_sq, slots):
                cand = lists[slots].reshape(-1)            # (nprobe*max_list,)
                ok = cand >= 0
                rows = grouped[slots].reshape(-1, grouped.shape[-1])
                row_sq = grouped_sq[slots].reshape(-1)
                s = -(q_sq - 2.0 * rows @ qv + row_sq)
                s = jnp.where(ok, s, -jnp.inf)
                v, p = jax.lax.top_k(s, kl_ivf)
                return v, jnp.where(ok, cand, -1)[p]

            return jax.vmap(one_query)(q_t, q2[:, 0], local)

        local_candidates = (flat_candidates if backend == "flat"
                            else ivf_candidates)
        n_slab_args = 3 if backend == "flat" else 7

        def body(tfm, *args):
            engine_mod._TRACE_COUNT[0] += 1
            slab_args = args[:n_slab_args]
            rest = args[n_slab_args:]
            if has_delta:
                vn_l, fn_l, dvt, dsq, dids, dvn, dfn, q, f = rest
            else:
                vn_l, fn_l, q, f = rest
            lin = _linear_shard_index(axes, sizes)
            qn, fqn = tfm.normalize(q, f)
            q_t = tfm.apply_normalized(qn, fqn, use_pallas=use_pallas)

            vals, gids = local_candidates(slab_args, q_t, lin)
            vals, gids = tree_merge_topk(vals, gids, axes, sizes, kp)
            # mirror the single-device id convention for unfillable rows
            gids = jnp.where(jnp.isneginf(vals), 0, jnp.maximum(gids, 0))

            cv = _gather_rows(vn_l, gids, lin, rows_local, axes)
            cf = _gather_rows(fn_l, gids, lin, rows_local, axes)
            score = fcvi.combined_score(cv, cf, qn, fqn, cfg.lam,
                                        use_pallas=use_pallas)
            scores, pos = jax.lax.top_k(score, k)
            ids = jnp.take_along_axis(gids, pos, axis=-1)

            if has_delta:
                dvals, dgids = _local_flat_topk(dvt, dsq, dids, q_t,
                                                min(kd, nld), use_pallas)
                dvals, dgids = tree_merge_topk(dvals, dgids, axes, sizes, kd)
                safe = jnp.maximum(dgids, 0)
                dcv = _gather_rows(dvn, safe, lin, nld, axes)
                dcf = _gather_rows(dfn, safe, lin, nld, axes)
                s = fcvi.combined_score(dcv, dcf, qn, fqn, cfg.lam,
                                        use_pallas=use_pallas)
                s = jnp.where(dgids >= 0, s, -jnp.inf)
                dv, dp = jax.lax.top_k(s, min(k, kd))
                did = index_size + jnp.take_along_axis(safe, dp, axis=-1)
                scores, ids = flat_mod.merge_topk(scores, ids, dv,
                                                  did.astype(ids.dtype), k)

            margin = scores[:, 0] - scores[:, -1]
            return scores, ids, margin

        row = P(axes)
        specs = (P(),) + self._slab_specs(row) + (row, row)
        if has_delta:
            specs = specs + (row, row, row, row, row)
        specs = specs + (P(), P())
        mapped = shard_map(body, mesh=self.mesh, in_specs=specs,
                           out_specs=(P(), P(), P()), check_vma=False)
        return jax.jit(mapped)
