"""Mesh-sharded serving: the engine batch step as a ``shard_map`` body.

``ShardedServing`` is the device-mesh counterpart of the single-device
``FCVIEngine`` hot path. The index's serving slab (``repro.index.slab``) is
sharded over the mesh — flat slabs by ROW, IVF slabs by LIST — together with
the normalized re-scoring originals and the engine's delta insert buffer, and
the whole per-batch computation runs as ONE jitted ``shard_map``:

  1. query transform: replicated (identical math on every shard; the fused
     Pallas kernel is preserved inside the shard when ``use_pallas`` is set),
  2. candidate generation: each shard scans only ITS slab block (kernel-backed
     when ``use_pallas``) and emits (score, global id) candidates,
  3. cross-shard merge: the per-axis tree top-k merge
     (``index.distributed.tree_merge_topk`` over ``flat.merge_topk``) reduces
     the per-shard sets to the exact global top-k' — only (k' x shards)
     candidate tuples ever cross the interconnect, never raw score matrices,
  4. re-ranking: candidate rows are fetched from the ROW-SHARDED normalized
     originals with a mask+psum distributed gather (each id is owned by
     exactly one shard; summing one real row with zeros is float-exact), then
     combined-scored exactly as the single-device path,
  5. delta merge: the per-shard delta buffer is searched locally, tree-merged,
     and folded in with the same shard-aware ``merge_topk``.

Parity contract: the sharded step returns results IDENTICAL to the
single-device ``engine._batch_step`` for any mesh shape (including 1 device)
— per-row arithmetic is unchanged, per-shard candidate sets provably contain
every global winner (a shard can hold at most k' of the global top-k', so
per-shard top-min(k', local) + exact tree merge loses nothing), and the
exact-refine / re-rank stages run on the same fp32 values.
``tests/test_sharded_engine.py`` enforces this on a forced 8-device host
mesh, kernels on and off.

Routed serving (``routing="routed"``): with filter-centric placement the
psi-transform makes filtered queries geometrically LOCAL — a query's
candidates concentrate on the few shards holding its nearby psi-clusters —
so the step additionally computes a per-query shard relevance mask IN-TRACE
and shards no query in the batch routes to skip candidate generation
entirely (the local scan runs inside a ``lax.cond``; the skipped branch
emits ``-inf`` candidates without touching the corpus slab):

  * IVF: a probed list is wholly owned by one shard
    (``ShardedIVFSlab.list_to_shard``), so masking shards that own none of a
    query's probed lists is EXACT — routed results equal dense-sharded
    results by construction, always.
  * flat (requires ``placement="cluster"``): the router probes the
    ``router_nprobe`` nearest psi-cluster centers and activates the shards
    holding their rows (``cluster_to_shard``). This can clip the dense
    top-k', so the step also emits a per-query soundness flag from the ball
    bound ||q - x|| >= ||q - mu_c|| - r_c over all clusters with rows on
    non-activated shards: if no clipped row can reach the k'-th routed
    candidate score, routed == dense bit-exactly; otherwise the engine
    re-runs the flagged queries through the dense step (the same sub-batch
    machinery as k' escalation), so end-to-end results stay identical.

The routed step returns two extra outputs — the flag and the (b, n_shards)
route mask — that the engine consumes OFF-trace for the fallback decision
and the router stats counters. ``route_signatures`` exposes the same router
rule host-side so the dispatch layer can sort a batch by shard-group
signature (co-routed queries land in the same padded batch, which is what
lets a shard's ``lax.cond`` actually skip).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import fcvi
from repro.index import flat as flat_mod
from repro.index import pq as pq_mod
from repro.index import slab as slab_mod
from repro.index.distributed import (linear_shard_index, tree_merge_topk,
                                     tree_merge_topk_rows)
from repro.kernels import ops

Array = jax.Array

# safety margin on the routed clipping check: the ball bound is exact in real
# arithmetic, but center distances / radii / refined candidate scores each
# carry ~1e-7-relative fp32 rounding. Scores are squared distances whose
# magnitude scales with the corpus, so the slack combines an absolute floor
# with a relative term (~100x fp32 eps) — conservative both near zero and on
# large-magnitude corpora (a few spurious dense fallbacks, never a missed
# one).
ROUTER_EPS = 1e-3
ROUTER_RTOL = 1e-5


def _gather_rows(local_rows: Array, gids: Array, lin, n_local: int, axes):
    """Distributed gather from a contiguously row-sharded array.

    ``local_rows`` is this shard's (n_local, ...) block of a (ns*n_local,
    ...) array; ``gids`` are global row ids (replicated). Each id is owned by
    exactly one shard: owners contribute the real row, everyone else zeros,
    and the psum reconstructs the gathered rows replicated. Adding zeros is
    float-exact, so the result is bit-identical to a local gather.
    """
    owner = gids // n_local
    mine = owner == lin
    loc = jnp.where(mine, gids % n_local, 0)
    part = jnp.where(mine[..., None], local_rows[loc], 0)
    for ax in axes:
        part = jax.lax.psum(part, ax)
    return part


def _local_flat_topk(vectors: Array, sq_norms: Array, row_ids: Array,
                     queries: Array, kl: int, use_pallas: bool,
                     scales: Optional[Array] = None):
    """Per-shard flat candidate generation with globally valid ids.

    Mirrors ``flat.search`` exactly (matmul-expansion candidate scores, then
    the fp32 exact-refine re-ordering), with padding rows (row_ids == -1,
    +inf squared norms) masked out of the refine so they can never outscore
    real rows. ``scales`` is the int8 storage rung's per-row dequant scale
    block (sharded like the slab; 1.0 on pads). Returns (vals, global ids,
    local slab positions) — the positions let the gather-free step pull the
    winners' re-rank payload rows from the shard-local payload block.
    """
    nl = vectors.shape[0]
    kl = min(kl, nl)
    kk = min(nl, kl + flat_mod.REFINE_PAD)
    if use_pallas:
        _, cand = ops.score_topk_padded(vectors, sq_norms, queries, kk,
                                        scales=scales)
    else:
        q2 = jnp.sum(queries * queries, axis=-1, keepdims=True)
        dot = queries @ vectors.astype(queries.dtype).T
        if scales is not None:
            dot = dot * scales[None, :]
        scores = -(q2 - 2.0 * dot + sq_norms[None, :])
        _, cand = jax.lax.top_k(scores, kk)
    vals, idx = flat_mod._exact_refine(vectors, queries, cand, kl,
                                       mask=row_ids >= 0, scales=scales)
    return vals, row_ids[idx], idx


def _cluster_bounds(q_t: Array, centers: Array, radii: Array):
    """Exact per-(query, cluster) center distances + ball-bound scores.

    Returns (d2 (b, ncl), ub (b, ncl)): ``ub`` is the best score (negative
    squared L2) any row of cluster c could reach for each query, from the
    triangle-inequality ball bound ||q - x|| >= ||q - mu_c|| - r_c. The
    distances are computed with the exact (non-expanded) formula — the bound
    must never be underestimated, so the matmul expansion's cancellation
    error is avoided. Shared by the shard router's clipping check and the
    degraded-mode coverage certificate.
    """
    d2 = jnp.sum(jnp.square(q_t[:, None, :] - centers[None]), axis=-1)
    ub = -jnp.square(jnp.maximum(jnp.sqrt(d2) - radii[None, :], 0.0))
    return d2, ub


def _flat_router(q_t: Array, centers: Array, radii: Array, incidence: Array,
                 router_nprobe: int, d2: Optional[Array] = None,
                 ub: Optional[Array] = None):
    """Per-query shard mask + clipping bound for cluster-placed flat slabs.

    q_t: (b, d) transformed queries; centers (ncl, d), radii (ncl,),
    incidence (ncl, ns) — the slab's routing tables. Probes the
    ``router_nprobe`` nearest psi-clusters per query and activates every
    shard holding rows of a probed cluster. Returns (route_mask (b, ns) bool,
    bound (b,)): ``bound`` is the best ball-bound score any row on a
    NON-activated shard could reach; the step compares it against the
    k'-th routed candidate to decide whether routing may have clipped.
    ``d2``/``ub`` accept precomputed ``_cluster_bounds`` output (the degraded
    step shares them with the coverage certificate).
    """
    ncl = centers.shape[0]
    r = min(router_nprobe, ncl)
    if d2 is None:
        d2, ub = _cluster_bounds(q_t, centers, radii)
    _, probe = jax.lax.top_k(-d2, r)
    probed = jnp.clip(
        jnp.sum(jax.nn.one_hot(probe, ncl, dtype=jnp.float32), axis=1),
        0.0, 1.0)                                            # (b, ncl)
    route_mask = (probed @ incidence) > 0.0                  # (b, ns)
    # clusters with at least one row on a non-activated shard may be clipped;
    # probed clusters never qualify (they activate all their shards)
    inactive = 1.0 - route_mask.astype(jnp.float32)
    clipped = (inactive @ incidence.T) > 0.0                 # (b, ncl)
    has_rows = jnp.sum(incidence, axis=-1) > 0.0             # (ncl,)
    bound = jnp.max(
        jnp.where(clipped & has_rows[None, :], ub, -jnp.inf), axis=-1)
    return route_mask, bound


@dataclasses.dataclass
class ShardedDelta:
    """Per-shard view of the engine's delta insert buffer (row-sharded)."""

    vt: Array       # (nd_pad, d) transformed delta rows, sharded
    sq: Array       # (nd_pad,) squared norms, +inf pads, sharded
    row_ids: Array  # (nd_pad,) delta-local ids, -1 pads, sharded
    vn: Array       # (nd_pad, d) normalized originals, sharded
    fn: Array       # (nd_pad, m) normalized filters, sharded
    nd: int         # real delta rows
    n_local: int    # rows per shard
    sc: Optional[Array] = None  # (nd_pad,) int8 dequant scales, 1.0 pads


class ShardedServing:
    """Sharded slabs + jitted shard_map steps for one (index, mesh) pair.

    Construction shards the serving state once (``slab.shard`` +
    row-sharding the re-rank originals); ``step`` lazily builds and caches
    one jitted shard_map per static (k, k', kd, delta-shape, routed)
    signature — exactly mirroring the jit cache structure of the
    single-device ``_batch_step``. ``routing="routed"`` enables the
    filter-routed step (see module docstring); on the flat backend it
    requires ``placement="cluster"``, and ``router_centers`` optionally pins
    the psi-cluster geometry (e.g. restored from a checkpoint so a restored
    engine routes identically).
    """

    def __init__(self, index, mesh, rules=None, *,
                 placement: str = "contiguous", routing: str = "dense",
                 router_nprobe: int = 0,
                 router_centers: Optional[Array] = None,
                 attrs: Optional[Array] = None):
        from repro.distributed.sharding import AxisRules

        if routing not in ("dense", "routed"):
            raise ValueError(
                f"routing must be 'dense' or 'routed', got {routing!r}")
        self.index = index
        self.mesh = mesh
        self.rules = rules if rules is not None else AxisRules(mesh)
        self.placement = placement
        self.routing = routing
        cfg = index.config
        if cfg.backend == "flat":
            if routing == "routed" and placement != "cluster":
                raise ValueError(
                    "routing='routed' on the flat backend requires "
                    "placement='cluster': the router needs the psi-cluster "
                    "ownership tables of filter-centric placement")
            self.slab = index.backend.slab().shard(
                mesh, self.rules, placement=placement, centers=router_centers,
                attrs=attrs)
        elif cfg.backend == "ivf":
            # "cluster" = filter-centric placement: affinity packing keeps a
            # query's co-probed lists on few shards (routing locality), where
            # plain "balanced" packing scatters them by load alone
            ivf_placement = "affinity" if placement == "cluster" else placement
            self.slab = index.backend.slab().shard(
                mesh, self.rules, placement=ivf_placement,
                list_sizes=index.backend.list_sizes, attrs=attrs)
        elif cfg.backend == "pq":
            if routing == "routed":
                raise ValueError(
                    "routing='routed' is not supported for the PQ backend: "
                    "ADC codes carry no per-shard routing geometry "
                    "(contiguous row placement only)")
            self.slab = index.backend.slab().shard(mesh, self.rules,
                                                   placement=placement)
        else:
            raise NotImplementedError(
                f"mesh-sharded serving supports the flat/ivf/pq backends, "
                f"not {cfg.backend!r}")
        self.axes = self.slab.axes
        self.sizes = tuple(mesh.shape[a] for a in self.axes)
        self.n_shards = slab_mod.axes_size(mesh, self.axes)
        # resolved flat-router probe count: default to ~two shards' worth of
        # psi-clusters — enough coverage that the clipping bound usually
        # certifies (few dense fallbacks) while localized filtered traffic
        # still leaves most shards unprobed
        if cfg.backend == "flat" and self.slab.router_centers is not None:
            ncl = self.slab.router_centers.shape[0]
            self.router_nprobe = (router_nprobe if router_nprobe > 0
                                  else max(1, (2 * ncl) // max(self.n_shards,
                                                               1)))
        else:
            self.router_nprobe = max(router_nprobe, 1)
        # normalized originals, contiguously row-sharded for the distributed
        # re-rank gather (independent of the slab's candidate placement)
        n = index.size
        self.rows_local = -(-n // max(self.n_shards, 1))
        n_pad = self.rows_local * self.n_shards
        self.vectors_n = self._put_rows(
            slab_mod.pad_dim0(index.vectors_n, n_pad, 0))
        self.filters_n = self._put_rows(
            slab_mod.pad_dim0(index.filters_n, n_pad, 0))
        self._steps = {}
        self._fsteps = {}      # filtered (predicate) steps, keyed (k, routed)
        self._payload = None   # gather-free payload slabs (lazy)

    def _put_rows(self, x: Array) -> Array:
        return jax.device_put(x, NamedSharding(self.mesh, P(self.axes)))

    # -- delta ------------------------------------------------------------
    def shard_delta(self, delta) -> ShardedDelta:
        """Shard the engine's device-resident delta buffer over the mesh."""
        nd = delta.vn.shape[0]
        nl = -(-nd // self.n_shards)
        nd_pad = nl * self.n_shards
        ids = jnp.concatenate(
            [jnp.arange(nd, dtype=jnp.int32),
             jnp.full((nd_pad - nd,), -1, jnp.int32)])
        sc = None
        if delta.flat.scales is not None:   # int8 delta: unit-scale pads
            sc = self._put_rows(
                slab_mod.pad_dim0(delta.flat.scales, nd_pad, 1.0))
        return ShardedDelta(
            vt=self._put_rows(
                slab_mod.pad_dim0(delta.flat.vectors, nd_pad, 0)),
            sq=self._put_rows(
                slab_mod.pad_dim0(delta.flat.sq_norms, nd_pad, jnp.inf)),
            row_ids=self._put_rows(ids),
            vn=self._put_rows(slab_mod.pad_dim0(delta.vn, nd_pad, 0)),
            fn=self._put_rows(slab_mod.pad_dim0(delta.fn, nd_pad, 0)),
            nd=nd, n_local=nl, sc=sc,
        )

    # -- dispatch-layer routing -------------------------------------------
    def route_signatures(self, q: np.ndarray, f: np.ndarray) -> np.ndarray:
        """Per-query active-shard bitmasks for dispatch-layer regrouping.

        q: (n, d) fp32 raw queries; f: (n, m) fp32 raw filter targets.
        Returns (n, ceil(n_shards/8)) uint8 packed bits (bit s set = the
        query routes to shard s), computed host-side with the same router
        rule the jitted routed step applies in-trace. Sorting a dispatch
        queue by signature groups co-routed queries into the same padded
        batch, which is what lets a shard's ``lax.cond`` skip fire.
        """
        idx = self.index
        cfg = idx.config
        qn, fqn = idx.transform.normalize(jnp.asarray(q), jnp.asarray(f))
        q_t = np.asarray(idx.transform.apply_normalized(qn, fqn), np.float32)
        n = q_t.shape[0]
        ns = self.n_shards
        mask = np.ones((n, ns), bool)
        # chunked, with the SAME distance formula and top-k tie-breaking as
        # the corresponding in-trace router (flat: exact diff; IVF jnp
        # coarse quantizer: matmul expansion), so the predicted signatures
        # match the step's route mask. The Pallas coarse kernel may break
        # exact centroid ties differently — grouping is best-effort there.
        chunk = 256   # bounds the flat (chunk, ncl, d) diff temporary
        if cfg.backend == "flat" and self.slab.router_centers is not None:
            c = np.asarray(self.slab.router_centers, np.float32)
            inc = np.asarray(self.slab.cluster_to_shard, np.float32)
            r = min(self.router_nprobe, c.shape[0])
            for s in range(0, n, chunk):
                qc = q_t[s:s + chunk]
                d2 = np.sum((qc[:, None, :] - c[None]) ** 2, axis=-1)
                probe = np.asarray(jax.lax.top_k(jnp.asarray(-d2), r)[1])
                probed = np.zeros((qc.shape[0], c.shape[0]), np.float32)
                probed[np.arange(qc.shape[0])[:, None], probe] = 1.0
                mask[s:s + chunk] = (probed @ inc) > 0.0
        elif cfg.backend == "ivf":
            c = np.asarray(self.slab.centroids, np.float32)
            c2 = np.asarray(self.slab.c_sq, np.float32)
            nprobe = min(cfg.nprobe, self.slab.nlist)
            l2s = np.asarray(self.slab.list_to_shard)
            for s in range(0, n, chunk):
                qc = q_t[s:s + chunk]
                q2 = np.sum(qc * qc, axis=-1, keepdims=True)
                cd = -(q2 - 2.0 * qc @ c.T + c2[None, :])
                probe = np.asarray(jax.lax.top_k(jnp.asarray(cd), nprobe)[1])
                m = np.zeros((qc.shape[0], ns), bool)
                m[np.arange(qc.shape[0])[:, None], l2s[probe]] = True
                mask[s:s + chunk] = m
        return np.packbits(mask, axis=1)

    # -- the sharded batch step -------------------------------------------
    def step(self, delta: Optional[ShardedDelta], q: Array, f: Array, *,
             k: int, kp: int, kd: int, routed: bool = False,
             alive: Optional[Array] = None, gather_free: bool = False):
        """One padded batch through the sharded hot path; same contract as
        ``engine._batch_step``: (scores (b, k), ids (b, k), margin (b,)).
        With ``routed=True`` two extra outputs follow: the per-query clipping
        flag (b,) bool (True = routing may have clipped the dense top-k';
        re-run dense) and the route mask (b, n_shards) bool.

        ``alive`` ((n_shards,) bool, or None = all healthy) switches to the
        DEGRADED step variant: shards marked dead take the zero-work
        ``lax.cond`` skip branch (dead == never-routed) and contribute no
        candidates, so results are exactly a search restricted to the
        surviving shards' slab rows; one more output ``uncovered`` (b,) bool
        follows (True = the dead shards could have held a top-k' candidate
        for this query — flat: psi-cluster ball-bound certificate; IVF:
        a probed list is owned by a dead shard; flat without routing tables:
        conservatively every query; PQ likewise). The mask is a TRACED
        argument, so marking further shards dead never retraces, and the
        healthy path's traces are untouched (separate jit-cache key).

        ``gather_free=True`` swaps the re-rank stage: each shard gathers its
        own winners' re-rank rows from its LOCAL payload block and computes
        their combined scores in place; the cross-shard merge then carries
        the finished scores (``tree_merge_topk_rows``) instead of one-hot
        psum-gathering rows after the merge — results stay bit-identical,
        but the step contains NO all-reduce collective.
        """
        degraded = alive is not None
        nld = None if delta is None else delta.n_local
        delta_scaled = delta is not None and delta.sc is not None
        key = (k, kp, kd, nld, routed, degraded, delta_scaled, gather_free)
        fn = self._steps.get(key)
        if fn is None:
            fn = self._steps[key] = self._build_step(
                k, kp, kd, nld, routed, degraded,
                delta_scaled=delta_scaled, gather_free=gather_free)
        args = (self.index.transform,) + self._slab_args(routed, degraded)
        if gather_free:
            args = args + self._rows_payload()
        else:
            args = args + (self.vectors_n, self.filters_n)
        if delta is not None:
            args = args + (delta.vt, delta.sq, delta.row_ids,
                           delta.vn, delta.fn)
            if delta_scaled:
                args = args + (delta.sc,)
        args = args + (q, f)
        if degraded:
            args = args + (jnp.asarray(alive, bool),)
        return fn(*args)

    def _rows_payload(self):
        """Slab-aligned re-rank payloads + replicated row-0 phantom rows for
        the gather-free step (lazy, cached): each shard re-ranks its own
        candidates from its OWN payload block instead of resolving ids
        through the mask+psum distributed gather. Flat: the normalized
        originals permuted into slab row order (contiguous placement aliases
        the row-sharded originals outright); IVF: the originals regrouped
        into this mesh's (slot, max_list, dim) layout; PQ: rows stay in
        corpus order, so the sharded originals ARE the payload. The row-0
        rows substitute unfillable (-inf) merge slots, mirroring the
        id-0 gather convention bit-exactly."""
        if self._payload is not None:
            return self._payload
        idx = self.index
        rep = NamedSharding(self.mesh, P())
        backend = idx.config.backend
        if backend == "flat":
            ids = jnp.asarray(np.asarray(self.slab.row_ids))
            if (self.placement == "contiguous"
                    and ids.shape[0] == self.vectors_n.shape[0]):
                pv, pf = self.vectors_n, self.filters_n
            else:
                keep = (ids >= 0)[:, None]
                safe = jnp.maximum(ids, 0)
                pv = self._put_rows(jnp.where(keep, idx.vectors_n[safe], 0.0))
                pf = self._put_rows(jnp.where(keep, idx.filters_n[safe], 0.0))
        elif backend == "ivf":
            from repro.index import ivf as ivf_mod
            lists = jnp.asarray(np.asarray(self.slab.lists))
            pv = self._put_rows(
                ivf_mod.build_grouped_payload(idx.vectors_n, lists))
            pf = self._put_rows(
                ivf_mod.build_grouped_payload(idx.filters_n, lists))
        else:   # pq: contiguous rows — the sharded originals alias directly
            pv, pf = self.vectors_n, self.filters_n
        vn0 = jax.device_put(idx.vectors_n[0], rep)
        fn0 = jax.device_put(idx.filters_n[0], rep)
        self._payload = (pv, pf, vn0, fn0)
        return self._payload

    def _has_flat_router(self) -> bool:
        return (self.index.config.backend == "flat"
                and self.slab.router_centers is not None)

    def slab_row_owner(self) -> np.ndarray:
        """(index.size,) int32 — shard owning each corpus row under the SLAB
        placement (flat: the row's slab block; IVF: its list's shard). This
        is the failure-domain map of degraded serving: a shard's death
        removes exactly the rows it owns here from the candidate space."""
        n = self.index.size
        owner = np.zeros((n,), np.int32)
        backend = self.index.config.backend
        if backend == "flat":
            ids = np.asarray(self.slab.row_ids).reshape(self.n_shards, -1)
            for s in range(self.n_shards):
                block = ids[s]
                owner[block[block >= 0]] = s
        elif backend == "ivf":
            l2s = np.asarray(self.slab.list_to_shard)
            lists = np.asarray(self.index.backend.lists)
            for g in range(lists.shape[0]):
                rows = lists[g]
                owner[rows[rows >= 0]] = l2s[g]
        else:
            # PQ: contiguous row blocks — ownership is pure position
            owner = (np.arange(n) // self.slab.n_local).astype(np.int32)
        return owner

    def _slab_args(self, routed: bool = False, degraded: bool = False):
        s = self.slab
        backend = self.index.config.backend
        if backend == "flat":
            base = (s.vectors, s.sq_norms, s.row_ids)
            # the degraded step needs the routing tables too (coverage
            # certificate), even when serving dense
            if (routed or degraded) and self._has_flat_router():
                base = base + (s.router_centers, s.router_radii,
                               s.cluster_to_shard)
            if s.scales is not None:     # int8 rung: per-row dequant scales
                base = base + (s.scales,)
            return base
        if backend == "ivf":
            base = (s.grouped, s.grouped_sq, s.valid, s.lists, s.centroids,
                    s.c_sq, s.slot_of_list)
            if s.grouped_scales is not None:
                base = base + (s.grouped_scales,)
            return base
        return (s.codes, s.coarse_ids, s.codebooks, s.coarse_centers,
                s.cb_sq, s.coarse_dot)

    def _slab_specs(self, row, routed: bool = False, degraded: bool = False):
        s = self.slab
        backend = self.index.config.backend
        if backend == "flat":
            base = (row, row, row)
            if (routed or degraded) and self._has_flat_router():
                base = base + (P(), P(), P())   # routing tables: replicated
            if s.scales is not None:
                base = base + (row,)
            return base
        if backend == "ivf":
            # grouped layouts are list-sharded; centroid state is replicated
            base = (row, row, row, row, P(), P(), P())
            if s.grouped_scales is not None:
                base = base + (row,)
            return base
        # PQ: per-row codes / coarse ids row-sharded; LUT state replicated
        return (row, row, P(), P(), P(), P())

    def _build_step(self, k: int, kp: int, kd: int, nld: Optional[int],
                    routed: bool, degraded: bool = False,
                    delta_scaled: bool = False, gather_free: bool = False):
        from repro.serve import engine as engine_mod

        cfg = self.index.config
        axes, sizes = self.axes, self.sizes
        ns = self.n_shards
        use_pallas = cfg.use_pallas
        backend = cfg.backend
        rows_local = self.rows_local
        index_size = self.index.size
        has_delta = nld is not None
        has_router = self._has_flat_router()
        router_np = self.router_nprobe
        has_scales = False
        n_local_pq = 0
        if backend == "flat":
            kl = min(kp, self.slab.n_local)
            has_scales = self.slab.scales is not None
        elif backend == "ivf":
            nprobe = min(cfg.nprobe, self.slab.nlist)
            lpp = self.slab.lists_per_shard + 1
            max_list = self.slab.max_list
            kl_ivf = min(kp, nprobe * max_list)
            has_scales = self.slab.grouped_scales is not None
        else:
            n_local_pq = self.slab.n_local
            kl_pq = min(kp, n_local_pq)
            pq_m, pq_ksub = self.slab.codebooks.shape[:2]
            pq_ncoarse = self.slab.coarse_centers.shape[0]

        def flat_scan(slab_args, q_t):
            vectors, sq_norms, row_ids = slab_args[:3]
            sc = slab_args[-1] if has_scales else None
            return _local_flat_topk(vectors, sq_norms, row_ids, q_t, kl,
                                    use_pallas, scales=sc)

        def flat_scan_sc(slab_args, pv_l, pf_l, q_t, qn, fqn):
            # gather-free: re-rank the shard's own candidates against its
            # LOCAL payload block (cheap local gather, no cross-shard
            # mask+psum) and let the merge carry the finished scores
            vals, gids, pos = flat_scan(slab_args, q_t)
            sc = fcvi.combined_score(pv_l[pos], pf_l[pos], qn, fqn, cfg.lam,
                                     use_pallas=use_pallas)
            return vals, gids, sc

        def ivf_probe(slab_args, q_t, q2):
            # coarse quantizer: replicated, identical to the single-device
            # path (centroid scoring is just a tiny flat search)
            c, c2 = slab_args[4], slab_args[5]
            if use_pallas:
                _, probe = ops.score_topk_padded(c, c2, q_t, nprobe)
            else:
                cd = -(q2 - 2.0 * q_t @ c.T + c2[None, :])
                _, probe = jax.lax.top_k(cd, nprobe)
            return probe

        def ivf_local_slots(slab_args, probe, lin):
            slot_of = slab_args[6]
            slot = slot_of[probe]                          # (b, nprobe)
            mine = (slot // lpp) == lin
            # non-local probes go to this shard's all-invalid sentinel slot
            return jnp.where(mine, slot % lpp, lpp - 1)

        def ivf_scan(slab_args, q_t, q2, probe, lin):
            grouped, grouped_sq, valid, lists = slab_args[:4]
            gsc = slab_args[-1] if has_scales else None
            local = ivf_local_slots(slab_args, probe, lin)
            if use_pallas:
                uniq, member = ops.dedup_probes(local.astype(jnp.int32), lpp)
                vals, flat_ids = ops.ivf_score_topk_dedup(
                    grouped, grouped_sq, valid, uniq, member, q_t, kl_ivf,
                    scales=gsc)
                cand = lists.reshape(-1)[flat_ids]         # -1 on pad slots
                return vals - q2, cand

            def one_query(qv, q_sq, slots):
                cand = lists[slots].reshape(-1)            # (nprobe*max_list,)
                ok = cand >= 0
                rows = grouped[slots].reshape(-1, grouped.shape[-1])
                row_sq = grouped_sq[slots].reshape(-1)
                dot = rows.astype(qv.dtype) @ qv
                if gsc is not None:
                    dot = dot * gsc[slots].reshape(-1)
                s = -(q_sq - 2.0 * dot + row_sq)
                s = jnp.where(ok, s, -jnp.inf)
                v, p = jax.lax.top_k(s, kl_ivf)
                return v, jnp.where(ok, cand, -1)[p]

            return jax.vmap(one_query)(q_t, q2[:, 0], local)

        def ivf_scan_sc(slab_args, pv_g, pf_g, q_t, q2, qn, fqn, probe, lin):
            # gather-free IVF scan: candidates' payload rows come from this
            # shard's grouped payload block by probed-slot position (local
            # gathers), are re-ranked here, and only the scores merge
            grouped, grouped_sq, valid, lists = slab_args[:4]
            gsc = slab_args[-1] if has_scales else None
            local = ivf_local_slots(slab_args, probe, lin)
            dv, dm = pv_g.shape[-1], pf_g.shape[-1]
            if use_pallas:
                uniq, member = ops.dedup_probes(local.astype(jnp.int32), lpp)
                vals, flat_ids = ops.ivf_score_topk_dedup(
                    grouped, grouped_sq, valid, uniq, member, q_t, kl_ivf,
                    scales=gsc)
                cand = lists.reshape(-1)[flat_ids]         # -1 on pad slots
                vals = vals - q2
                rv = pv_g.reshape(-1, dv)[flat_ids]
                rf = pf_g.reshape(-1, dm)[flat_ids]
            else:

                def one_query(qv, q_sq, slots):
                    cand = lists[slots].reshape(-1)
                    ok = cand >= 0
                    rows = grouped[slots].reshape(-1, grouped.shape[-1])
                    row_sq = grouped_sq[slots].reshape(-1)
                    dot = rows.astype(qv.dtype) @ qv
                    if gsc is not None:
                        dot = dot * gsc[slots].reshape(-1)
                    s = -(q_sq - 2.0 * dot + row_sq)
                    s = jnp.where(ok, s, -jnp.inf)
                    v, p = jax.lax.top_k(s, kl_ivf)
                    rpv = pv_g[slots].reshape(-1, dv)
                    rpf = pf_g[slots].reshape(-1, dm)
                    return v, jnp.where(ok, cand, -1)[p], rpv[p], rpf[p]

                vals, cand, rv, rf = jax.vmap(one_query)(q_t, q2[:, 0], local)
            sc = fcvi.combined_score(rv, rf, qn, fqn, cfg.lam,
                                     use_pallas=use_pallas)
            return vals, cand, sc

        def pq_scan(slab_args, q_t, lin):
            """Local ADC sweep over this shard's code block; returns
            (vals, local positions). Per-row ADC sums depend only on the
            row's own codes + the replicated LUTs, so local values equal
            the single-device scan's entries bitwise; position-masked pad
            rows (codes 0) score -inf."""
            codes, cids = slab_args[0], slab_args[1]
            pidx = pq_mod.PQIndex(codebooks=slab_args[2], codes=codes,
                                  coarse_centers=slab_args[3],
                                  coarse_ids=cids, cb_sq=slab_args[4],
                                  coarse_dot=slab_args[5])
            luts = pq_mod.compute_luts(pidx, q_t, use_pallas=use_pallas)
            nq = luts.shape[0]
            if use_pallas:
                ccodes = cids[:, None] * pq_ksub + codes
                big = luts.transpose(0, 2, 1, 3).reshape(
                    nq, pq_m, pq_ncoarse * pq_ksub)
                d2 = ops.pq_score_batch(ccodes, big)       # (b, n_local)
            else:
                pos = (cids[:, None] * (pq_m * pq_ksub)
                       + jnp.arange(pq_m)[None, :] * pq_ksub + codes)

                def one_query(lut):
                    return jnp.sum(lut.reshape(-1)[pos], axis=-1)

                d2 = jax.vmap(one_query)(luts)
            rowpos = lin * n_local_pq + jnp.arange(codes.shape[0])
            s = jnp.where((rowpos < index_size)[None, :], -d2, -jnp.inf)
            return jax.lax.top_k(s, kl_pq)

        if backend == "flat":
            n_slab_args = (3 + (3 if (routed or degraded) and has_router
                                else 0) + (1 if has_scales else 0))
        elif backend == "ivf":
            n_slab_args = 7 + (1 if has_scales else 0)
        else:
            n_slab_args = 6

        def body(tfm, *args):
            engine_mod._TRACE_COUNT[0] += 1
            slab_args = args[:n_slab_args]
            rest = args[n_slab_args:]
            alive_v = None
            if degraded:
                alive_v = rest[-1]                 # (ns,) bool, replicated
                rest = rest[:-1]
            vn0 = fn0 = None
            if gather_free:
                # slab-aligned payload blocks + replicated row-0 phantoms
                pv_l, pf_l, vn0, fn0 = rest[:4]
                rest = rest[4:]
            else:
                vn_l, fn_l = rest[:2]
                rest = rest[2:]
            dsc = None
            if has_delta:
                if delta_scaled:
                    dvt, dsq, dids, dvn, dfn, dsc, q, f = rest
                else:
                    dvt, dsq, dids, dvn, dfn, q, f = rest
            else:
                q, f = rest
            lin = linear_shard_index(axes, sizes)
            ok_me = alive_v[lin] if degraded else None   # this shard alive?
            qn, fqn = tfm.normalize(q, f)
            q_t = tfm.apply_normalized(qn, fqn, use_pallas=use_pallas)
            b = q.shape[0]

            route_mask = bound = None
            shard_of = cl_ub = inc = None
            if backend == "flat":
                if (routed or degraded) and has_router:
                    rc, rr, inc = slab_args[3:6]
                    cl_d2, cl_ub = _cluster_bounds(q_t, rc, rr)

                def scan(_):
                    if gather_free:
                        out = flat_scan_sc(slab_args, pv_l, pf_l, q_t,
                                           qn, fqn)
                    else:
                        v, g, _ = flat_scan(slab_args, q_t)
                        out = (v, g)
                    if routed and has_router:
                        # routing masks VALUES only; carried local scores
                        # stay attached and lose the merge as -inf slots
                        out = ((jnp.where(mine_q[:, None], out[0],
                                          -jnp.inf),) + out[1:])
                    return out

                def skip(_):
                    out = (jnp.full((b, kl), -jnp.inf, jnp.float32),
                           jnp.zeros((b, kl), jnp.int32))
                    if gather_free:
                        out = out + (jnp.zeros((b, kl), jnp.float32),)
                    return out

                if routed and has_router:
                    route_mask, bound = _flat_router(q_t, rc, rr, inc,
                                                     router_np, d2=cl_d2,
                                                     ub=cl_ub)
                    mine_q = jnp.take(route_mask, lin, axis=1)   # (b,)
                    pred = jnp.any(mine_q)
                    if degraded:     # dead == never-routed: zero-work branch
                        pred = jnp.logical_and(pred, ok_me)
                    out = jax.lax.cond(pred, scan, skip, None)
                elif degraded:
                    out = jax.lax.cond(ok_me, scan, skip, None)
                    if routed:   # 1-shard mesh: routing is a no-op
                        route_mask = jnp.ones((b, ns), bool)
                else:
                    out = scan(None)
                    if routed:   # 1-shard mesh: routing is a no-op
                        route_mask = jnp.ones((b, ns), bool)
            elif backend == "ivf":
                q2 = jnp.sum(q_t * q_t, axis=-1, keepdims=True)
                probe = ivf_probe(slab_args, q_t, q2)
                if routed or degraded:
                    # a probed list is wholly owned by one shard; the routed
                    # mask is exact, and the degraded coverage certificate
                    # just checks probed-list ownership against the mask
                    shard_of = slab_args[6][probe] // lpp      # (b, nprobe)

                def scan(_):
                    if gather_free:
                        return ivf_scan_sc(slab_args, pv_l, pf_l, q_t, q2,
                                           qn, fqn, probe, lin)
                    return ivf_scan(slab_args, q_t, q2, probe, lin)

                def skip(_):
                    out = (jnp.full((b, kl_ivf), -jnp.inf, jnp.float32),
                           jnp.full((b, kl_ivf), -1, jnp.int32))
                    if gather_free:
                        out = out + (jnp.zeros((b, kl_ivf), jnp.float32),)
                    return out

                if routed:
                    route_mask = jnp.any(
                        shard_of[:, :, None] == jnp.arange(ns)[None, None, :],
                        axis=1)                                # (b, ns)
                    mine_q = jnp.take(route_mask, lin, axis=1)
                    pred = jnp.any(mine_q)
                    if degraded:
                        pred = jnp.logical_and(pred, ok_me)
                    out = jax.lax.cond(pred, scan, skip, None)
                elif degraded:
                    out = jax.lax.cond(ok_me, scan, skip, None)
                else:
                    out = scan(None)
            else:   # pq (routed is rejected at construction)

                def scan(_):
                    vals, p = pq_scan(slab_args, q_t, lin)
                    gids = lin * n_local_pq + p
                    if gather_free:
                        # contiguous ownership: local position p IS the row
                        sc = fcvi.combined_score(pv_l[p], pf_l[p], qn, fqn,
                                                 cfg.lam,
                                                 use_pallas=use_pallas)
                        return vals, gids, sc
                    return vals, gids

                def skip(_):
                    out = (jnp.full((b, kl_pq), -jnp.inf, jnp.float32),
                           jnp.zeros((b, kl_pq), jnp.int32))
                    if gather_free:
                        out = out + (jnp.zeros((b, kl_pq), jnp.float32),)
                    return out

                if degraded:
                    out = jax.lax.cond(ok_me, scan, skip, None)
                else:
                    out = scan(None)

            if gather_free:
                vals, gids, sc = out
                vals, gids, (scc,) = tree_merge_topk_rows(
                    vals, gids, (sc[..., None],), axes, sizes, kp)
            else:
                vals, gids = out
                vals, gids = tree_merge_topk(vals, gids, axes, sizes, kp)
            if routed:
                if backend == "flat" and has_router:
                    # may routing have clipped the dense top-k'? A -inf
                    # k'-th value (routed pool could not even fill k') makes
                    # the slack infinite and always flags, as it must — a
                    # masked shard might have filled it. In degraded mode the
                    # bound still counts dead inactive shards, which only
                    # over-flags: the dense fallback also serves without the
                    # dead shards, so routed == dense-degraded either way.
                    kth = vals[:, -1]
                    tol = ROUTER_EPS + ROUTER_RTOL * jnp.abs(kth)
                    flag = bound >= kth - tol
                else:
                    # IVF routing (and the 1-shard flat no-op) is exact by
                    # construction: masked shards own none of the probed
                    # lists, so even an underfilled pool matches dense
                    flag = jnp.zeros((b,), bool)
            if degraded:
                # coverage certificate vs the HEALTHY corpus: could the dead
                # shards have held a top-k' candidate for this query?
                kth = vals[:, -1]
                if backend == "flat" and has_router:
                    # ball bound over psi-clusters with rows on dead shards,
                    # same tolerance discipline as the router clipping check;
                    # a -inf k'-th value conservatively flags
                    dead_f = 1.0 - alive_v.astype(jnp.float32)
                    dead_cl = (inc @ dead_f) > 0.0             # (ncl,)
                    has_rows = jnp.sum(inc, axis=-1) > 0.0
                    dead_bound = jnp.max(
                        jnp.where((dead_cl & has_rows)[None, :], cl_ub,
                                  -jnp.inf), axis=-1)
                    tol = ROUTER_EPS + ROUTER_RTOL * jnp.abs(kth)
                    uncovered = dead_bound >= kth - tol
                elif backend == "ivf":
                    # exact: the query is affected iff a probed list is
                    # owned by a dead shard
                    uncovered = jnp.any(
                        jnp.logical_not(alive_v[shard_of]), axis=1)
                else:
                    # contiguous flat/PQ placement has no routing geometry:
                    # conservatively flag every query while any shard is dead
                    uncovered = jnp.broadcast_to(
                        jnp.any(jnp.logical_not(alive_v)), (b,))
            # mirror the single-device id convention for unfillable rows
            gids = jnp.where(jnp.isneginf(vals), 0, jnp.maximum(gids, 0))

            if gather_free:
                # -inf merge slots mirror the legacy forced-gid-0 gather:
                # score the replicated corpus-row-0 phantom through the same
                # gather-fed rescore tile shape convention and substitute it
                # where the merge left -inf
                z = jnp.zeros((b, 1), jnp.int32)
                s0 = fcvi.combined_score(vn0[None][z], fn0[None][z], qn, fqn,
                                         cfg.lam, use_pallas=use_pallas)
                score = jnp.where(jnp.isneginf(vals), s0, scc[..., 0])
            else:
                cv = _gather_rows(vn_l, gids, lin, rows_local, axes)
                cf = _gather_rows(fn_l, gids, lin, rows_local, axes)
                score = fcvi.combined_score(cv, cf, qn, fqn, cfg.lam,
                                            use_pallas=use_pallas)
            scores, pos = jax.lax.top_k(score, k)
            ids = jnp.take_along_axis(gids, pos, axis=-1)

            if has_delta:
                kdl = min(kd, nld)
                if gather_free:
                    dvals, dgids, dpos = _local_flat_topk(dvt, dsq, dids,
                                                          q_t, kdl,
                                                          use_pallas,
                                                          scales=dsc)
                    ds = fcvi.combined_score(dvn[dpos], dfn[dpos], qn, fqn,
                                             cfg.lam, use_pallas=use_pallas)
                    dvals, dgids, (dss,) = tree_merge_topk_rows(
                        dvals, dgids, (ds[..., None],), axes, sizes, kd)
                    s = dss[..., 0]
                else:
                    dvals, dgids, _ = _local_flat_topk(dvt, dsq, dids, q_t,
                                                       kdl, use_pallas,
                                                       scales=dsc)
                    dvals, dgids = tree_merge_topk(dvals, dgids, axes,
                                                   sizes, kd)
                    safe = jnp.maximum(dgids, 0)
                    dcv = _gather_rows(dvn, safe, lin, nld, axes)
                    dcf = _gather_rows(dfn, safe, lin, nld, axes)
                    s = fcvi.combined_score(dcv, dcf, qn, fqn, cfg.lam,
                                            use_pallas=use_pallas)
                s = jnp.where(dgids >= 0, s, -jnp.inf)
                safe = jnp.maximum(dgids, 0)
                dv, dp = jax.lax.top_k(s, min(k, kd))
                did = index_size + jnp.take_along_axis(safe, dp, axis=-1)
                scores, ids = flat_mod.merge_topk(scores, ids, dv,
                                                  did.astype(ids.dtype), k)

            margin = scores[:, 0] - scores[:, -1]
            out = (scores, ids, margin)
            if routed:
                out = out + (flag, route_mask)
            if degraded:
                out = out + (uncovered,)
            return out

        row = P(axes)
        specs = (P(),) + self._slab_specs(row, routed, degraded)
        if gather_free:
            specs = specs + (row, row, P(), P())   # payloads + row-0 phantoms
        else:
            specs = specs + (row, row)
        if has_delta:
            specs = specs + (row,) * 5
            if delta_scaled:
                specs = specs + (row,)
        specs = specs + (P(), P())
        if degraded:
            specs = specs + (P(),)     # alive mask: replicated, traced
        n_out = (5 if routed else 3) + (1 if degraded else 0)
        mapped = shard_map(body, mesh=self.mesh, in_specs=specs,
                           out_specs=(P(),) * n_out, check_vma=False)
        return jax.jit(mapped)

    # -- the sharded filtered (predicate) step ----------------------------
    def filtered_step(self, q_t: Array, lo: Array, hi: Array,
                      isin_vals: Array, isin_count: Array, *, k: int,
                      routed: bool = False):
        """Exact predicate-filtered top-k over the sharded slab.

        ``q_t`` is the (b, d) fold-transformed query batch (computed once by
        the engine, replicated in); the four predicate arrays are the
        fixed-shape ``CompiledPredicate`` encoding — pure DATA operands, so
        one trace per (k, routed) signature serves every predicate. Each
        shard evaluates the predicate over its slab-resident RAW attribute
        block (NaN pad/sentinel rows are never eligible), computes the exact
        fp32 squared distances of its ELIGIBLE rows with the same elementwise
        expression as ``flat.filtered_d2``, and emits its local (d2, id)
        top-k under the deterministic (d2 asc, id asc) order; the per-shard
        sets merge by the same two-key sort outside the shard_map. Results
        are bit-identical to the single-device MASK plan.

        ``routed=True`` wraps each shard's scan in a ``lax.cond`` that skips
        the distance work when NO local row is eligible — exact by
        construction (ineligible rows contribute (+inf, DEAD) either way),
        it only changes which code runs. Returns (d2 (b, k), ids (b, k)) in
        the pre-finalize convention (dead slots (+inf, DEAD_ID)) so the
        engine can merge the delta tier in d2-space before
        ``flat.finalize_filtered``.
        """
        if self.slab.attrs is None:
            raise ValueError(
                "filtered_step needs attribute columns on the slab: "
                "construct ShardedServing(..., attrs=<raw (n, m) table>)")
        key = (k, routed)
        fn = self._fsteps.get(key)
        if fn is None:
            fn = self._fsteps[key] = self._build_filtered_step(k, routed)
        return fn(*self._fslab_args(), q_t, lo, hi, isin_vals, isin_count)

    def _fslab_args(self):
        s = self.slab
        if self.index.config.backend == "flat":
            base = (s.vectors, s.row_ids, s.attrs)
            if s.scales is not None:
                base = base + (s.scales,)
            return base
        base = (s.grouped, s.lists, s.attrs)
        if s.grouped_scales is not None:
            base = base + (s.grouped_scales,)
        return base

    def _build_filtered_step(self, k: int, routed: bool):
        from repro.core import filters as filters_mod
        from repro.serve import engine as engine_mod

        backend = self.index.config.backend
        if backend not in ("flat", "ivf"):
            raise ValueError(
                f"filtered serving supports the flat/ivf backends, "
                f"not {backend!r}")
        axes, ns = self.axes, self.n_shards
        has_scales = (self.slab.scales is not None if backend == "flat"
                      else self.slab.grouped_scales is not None)

        def body(*args):
            engine_mod._TRACE_COUNT[0] += 1
            if has_scales:
                vecs, ids_raw, attrs, scales = args[:4]
                rest = args[4:]
            else:
                vecs, ids_raw, attrs = args[:3]
                scales = None
                rest = args[3:]
            q_t, lo, hi, iv, ic = rest
            b = q_t.shape[0]
            if backend == "ivf":
                # flatten the (slot, max_list, ...) grouped layout to rows
                d = vecs.shape[-1]
                vecs = vecs.reshape(-1, d)
                ids_raw = ids_raw.reshape(-1)
                attrs = attrs.reshape(-1, attrs.shape[-1])
                if scales is not None:
                    scales = scales.reshape(-1)
            elig = filters_mod.eval_mask(attrs, lo, hi, iv, ic)
            elig = jnp.logical_and(elig, ids_raw >= 0)
            ids = jnp.where(elig, ids_raw,
                            flat_mod.DEAD_ID).astype(jnp.int32)

            def scan(_):
                rows = vecs.astype(jnp.float32)
                if scales is not None:
                    rows = rows * scales[:, None]
                d2 = flat_mod.filtered_d2(q_t, rows)          # (b, n_local)
                d2 = jnp.where(elig[None, :], d2, jnp.inf)
                return flat_mod.lexsort_topk(
                    d2, jnp.broadcast_to(ids[None, :], d2.shape), k)

            def skip(_):
                return (jnp.full((b, k), jnp.inf, jnp.float32),
                        jnp.full((b, k), flat_mod.DEAD_ID, jnp.int32))

            if routed:
                return jax.lax.cond(jnp.any(elig), scan, skip, None)
            return scan(None)

        row = P(axes)
        n_in = 4 if has_scales else 3
        mapped = shard_map(
            body, mesh=self.mesh,
            in_specs=(row,) * n_in + (P(),) * 5,
            out_specs=(P(axes), P(axes)), check_vma=False)

        def step(*args):
            d2, ids = mapped(*args)                  # (ns*b, k) stacked
            b = args[n_in].shape[0]
            d2 = d2.reshape(ns, b, k).transpose(1, 0, 2).reshape(b, ns * k)
            ids = ids.reshape(ns, b, k).transpose(1, 0, 2).reshape(b, ns * k)
            return flat_mod.lexsort_topk(d2, ids, k)

        return jax.jit(step)
