"""Sharded checkpointing with elastic restore (no orbax dependency).

Layout:  <dir>/step_<N>/
           manifest.json        — tree structure, shapes, dtypes, mesh metadata
           arrays.npz           — one entry per leaf (flattened path keys)

Writes are atomic (tmp dir + rename) so a crash mid-save never corrupts the
latest checkpoint — the fault-tolerance contract is "the newest complete
step_* directory is always loadable". ``restore`` accepts ANY target mesh:
arrays are loaded replicated and re-laid-out via device_put with the target
sharding, which is exactly the elastic-restart path (node loss -> smaller
mesh -> resume).

Integrity: ``save`` records a crc32 per stored array in the manifest;
``load``/``restore`` verify every array against it (and against the manifest
key set) before handing anything back, so torn, truncated, or bit-flipped
checkpoint files surface as ``CheckpointCorruptError`` instead of a crash
mid-restore or silently wrong state. When no explicit ``step`` is requested,
both fall back from a corrupt newest step to the newest INTACT one (with a
warning) — the crash-only recovery contract extends to on-disk corruption.
Manifests written before checksums existed load without verification.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import warnings
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

SEP = "|"


class CheckpointCorruptError(RuntimeError):
    """A checkpoint step failed integrity verification (torn/truncated file,
    checksum mismatch, unreadable manifest, or missing arrays)."""


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


_VIEW_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                "float8_e5m2": np.uint8}


def _to_storable(arr: np.ndarray) -> np.ndarray:
    """npz can't store ml_dtypes (bf16/f8) — view as same-width uints; the
    true dtype is recorded in the manifest and restored on load."""
    name = str(arr.dtype)
    if name in _VIEW_DTYPES:
        return arr.view(_VIEW_DTYPES[name])
    return arr


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_DTYPES:
        import ml_dtypes
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def save(ckpt_dir: str, step: int, tree: Any, metadata: Optional[dict] = None,
         keep: int = 3) -> str:
    """Atomically write one checkpoint step; returns the step directory.

    ``tree``: any pytree of arrays (jax or numpy; sharded jax arrays are
    gathered to host by ``np.asarray``). Shapes/dtypes are recorded in the
    manifest; bf16/f8 leaves are stored as same-width uint views and
    restored to their true dtype on load. ``metadata``: JSON-serializable
    dict stored in the manifest (configs, serving knobs). ``keep``: older
    step directories beyond this count are garbage-collected (0 keeps all).
    The write is tmp-dir + rename, so a crash mid-save never corrupts the
    newest complete step.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    treedef = jax.tree_util.tree_structure(tree)
    storable = {k: np.ascontiguousarray(_to_storable(v))
                for k, v in flat.items()}
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "checksums": {k: zlib.crc32(v.tobytes())
                      for k, v in storable.items()},
        "metadata": metadata or {},
    }
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **storable)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def _read_step(step_dir: str) -> tuple:
    """Read + VERIFY one step directory; returns (manifest, {key: array}).

    Everything is read eagerly so truncation/zip damage surfaces here, and
    every array is checked against the manifest's crc32 (when present — older
    manifests without ``checksums`` load unverified). Any failure raises
    ``CheckpointCorruptError``; callers with ``step=None`` use that to fall
    back to an older intact step.
    """
    try:
        with open(os.path.join(step_dir, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(
            f"{step_dir}: unreadable manifest ({e})") from e
    checksums = manifest.get("checksums")
    data = {}
    try:
        with np.load(os.path.join(step_dir, "arrays.npz")) as npz:
            for key in manifest["keys"]:
                if key not in npz:
                    raise CheckpointCorruptError(
                        f"{step_dir}: array {key!r} missing from arrays.npz")
                data[key] = np.ascontiguousarray(npz[key])
    except CheckpointCorruptError:
        raise
    except Exception as e:  # zipfile/pickle/OS errors on torn files
        raise CheckpointCorruptError(
            f"{step_dir}: unreadable arrays.npz ({e})") from e
    if checksums is not None:
        for key, arr in data.items():
            want = checksums.get(key)
            got = zlib.crc32(arr.tobytes())
            if want != got:
                raise CheckpointCorruptError(
                    f"{step_dir}: checksum mismatch for {key!r} "
                    f"(manifest {want}, file {got})")
    return manifest, data


def _read_verified(ckpt_dir: str, step: Optional[int]) -> tuple:
    """Resolve ``step`` and read it verified; ``step=None`` walks newest ->
    oldest to the first INTACT step (warning per corrupt one skipped).
    Returns (manifest, data, step)."""
    if step is not None:
        manifest, data = _read_step(
            os.path.join(ckpt_dir, f"step_{step:08d}"))
        return manifest, data, step
    steps = all_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    last_err = None
    for s in reversed(steps):
        try:
            manifest, data = _read_step(
                os.path.join(ckpt_dir, f"step_{s:08d}"))
            return manifest, data, s
        except CheckpointCorruptError as e:
            warnings.warn(f"skipping corrupt checkpoint step {s}: {e}")
            last_err = e
    raise CheckpointCorruptError(
        f"{ckpt_dir}: every checkpoint step is corrupt "
        f"(newest error: {last_err})")


def load(ckpt_dir: str, step: Optional[int] = None) -> tuple:
    """Template-free restore: rebuild the NESTED DICT tree from the manifest.

    The engine-facing entry point of the elastic lifecycle: callers that
    saved a dict pytree (e.g. ``fcvi.index_state``) get it back as plain
    nested dicts of HOST numpy arrays — replicated, ready to be re-laid-out
    onto whatever mesh the restoring process has (``slab.shard`` /
    ``ShardedServing`` do the device_put). Dtypes are restored from the
    manifest (bf16/f8 round-trip through the uint view). Integrity is
    verified before anything is returned; with ``step=None`` a corrupt
    newest step falls back to the newest intact one. Returns
    (tree, step, metadata).
    """
    manifest, data, step = _read_verified(ckpt_dir, step)
    tree: dict = {}
    for key in manifest["keys"]:
        arr = _from_storable(data[key], manifest["dtypes"][key])
        node = tree
        parts = key.split(SEP)
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return tree, step, manifest["metadata"]


def restore(ckpt_dir: str, template: Any, step: Optional[int] = None,
            shardings: Any = None) -> tuple:
    """Restore into ``template``'s tree structure (shapes are validated).

    ``shardings``: optional matching tree of NamedShardings for the TARGET
    mesh — this is the elastic-reshard path; None keeps arrays on the default
    device. Integrity-verified like ``load`` (corrupt newest step falls back
    when ``step=None``).
    Returns (tree, step, metadata).
    """
    manifest, data, step = _read_verified(ckpt_dir, step)

    flat_template = _flatten(template)
    if sorted(flat_template) != manifest["keys"]:
        missing = set(manifest["keys"]) ^ set(flat_template)
        raise ValueError(f"checkpoint/template key mismatch: {sorted(missing)[:8]}")

    flat_shardings = _flatten(shardings) if shardings is not None else {}
    leaves = []
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    for path, leaf in paths:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = _from_storable(data[key], manifest["dtypes"][key])
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != template {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        if key in flat_shardings:
            leaves.append(jax.device_put(arr, flat_shardings[key]))
        else:
            leaves.append(jnp.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
    return tree, step, manifest["metadata"]
