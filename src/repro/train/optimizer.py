"""AdamW + schedules, pure JAX (no optax dependency).

Mixed-precision recipe (the large-scale standard): compute params may be
bf16; the optimizer keeps an f32 MASTER copy plus f32 moments. Master +
moments are ZeRO-1-sharded over the data axis by the caller (through
in/out_shardings on the train step), so per-device optimizer state is
3 x params_bytes / dp_size.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: Array
    mu: Any
    nu: Any
    master: Any   # f32 master weights (authoritative; params are its cast)


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros), master=master)


def schedule(cfg: AdamWConfig, step: Array) -> Array:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(master, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * master
        return master - lr * delta

    new_master = jax.tree.map(upd, state.master, mu, nu)
    new_params = jax.tree.map(lambda mp, p: mp.astype(p.dtype),
                              new_master, params)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step=step, mu=mu, nu=nu,
                                  master=new_master), metrics
