"""Train-step factory: loss -> grad -> AdamW, with microbatch accumulation.

``make_train_step`` returns a pure function suitable for jax.jit with
in/out shardings. Gradient accumulation runs as a lax.scan over microbatches
(activation memory / n_micro); the paper-scale MoE archs set n_micro > 1.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.train import optimizer as opt

Array = jax.Array


def make_loss_fn(cfg):
    def loss_fn(params, batch):
        return M.lm_loss(params, cfg, batch)
    return loss_fn


def make_train_step(cfg, adamw: opt.AdamWConfig, n_micro: int = 1,
                    grad_shardings=None):
    """grad_shardings: optional NamedSharding tree for gradients (ZeRO:
    constraining grads to the data-sharded master layout turns the DP
    all-reduce into a reduce-scatter and keeps optimizer math sharded)."""
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g, grad_shardings)

    def train_step(params, opt_state, batch):
        if n_micro <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = constrain(grads)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                acc, loss_acc = carry
                (loss, _), grads = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / n_micro, acc, grads)
                return (constrain(acc), loss_acc + loss / n_micro), None

            (grads, loss), _ = jax.lax.scan(
                body, (constrain(zeros), 0.0), micro)
            metrics = {"loss": loss}

        new_params, new_opt, opt_metrics = opt.update(adamw, grads, opt_state, params)
        metrics = {**metrics, **opt_metrics}
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg):
    loss_fn = make_loss_fn(cfg)

    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics

    return eval_step
