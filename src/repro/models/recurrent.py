"""Recurrent mixers: RG-LRU (Griffin/RecurrentGemma) and xLSTM (mLSTM/sLSTM).

TPU adaptation notes (DESIGN.md §6):
* RG-LRU is a diagonal linear recurrence -> jax.lax.associative_scan
  (log-depth, parallel) instead of the paper's sequential CUDA kernel.
* mLSTM uses the chunkwise-parallel formulation (intra-chunk quadratic on the
  MXU + inter-chunk state scan) — O(S*L) memory, exact, trains through scan.
* sLSTM has true hidden-to-hidden recurrence (non-parallelizable by design);
  it runs as a sequential lax.scan with f32 stabilized exponential gating.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_act
from repro.models.layers import COMPUTE_DTYPE, _normal

Array = jax.Array


# ---------------------------------------------------------------------------
# Temporal (depthwise causal) conv — Griffin's width-4 conv
# ---------------------------------------------------------------------------

def causal_conv1d(x: Array, w: Array, state: Optional[Array] = None):
    """x: (b, s, c); w: (width, c) depthwise. state: (b, width-1, c) history.

    Returns (y, new_state).
    """
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    new_state = xp[:, xp.shape[1] - (width - 1):]
    return y, new_state


# ---------------------------------------------------------------------------
# RG-LRU (Real-Gated Linear Recurrent Unit)
# ---------------------------------------------------------------------------

RGLRU_C = 8.0


def init_rglru(rng, d: int, d_rnn: int, conv_width: int = 4):
    ks = jax.random.split(rng, 6)
    std = 1.0 / math.sqrt(d)
    stdr = 1.0 / math.sqrt(d_rnn)
    # Lambda init so a = sigmoid(lam)^(c*r) sits in [0.9, 0.999]
    u = jax.random.uniform(ks[5], (d_rnn,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1.0 / RGLRU_C) / (1 - u ** (1.0 / RGLRU_C)))
    return {
        "w_rnn_in": _normal(ks[0], (d, d_rnn), std),
        "w_rnn_gate": _normal(ks[1], (d, d_rnn), std),
        "conv_w": _normal(ks[2], (conv_width, d_rnn), stdr),
        "w_gate_a": _normal(ks[3], (d_rnn, d_rnn), stdr),
        "w_gate_x": _normal(ks[4], (d_rnn, d_rnn), stdr),
        "lam": lam,
    }


def _rglru_gates(params, u: Array):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ params["w_gate_a"])
    i = jax.nn.sigmoid(uf @ params["w_gate_x"])
    log_a = -RGLRU_C * r * jax.nn.softplus(params["lam"])  # log sigmoid(lam)^(c r)
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) input normalization (Griffin eq. 4)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i * uf


def rglru_scan(a: Array, bx: Array, h0: Optional[Array] = None):
    """h_t = a_t * h_{t-1} + bx_t over axis 1 via associative scan."""
    if h0 is not None:
        # fold initial state into the first step
        bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


def rglru_block(params, x: Array, cache: Optional[dict] = None):
    """Griffin recurrent block. x: (b, s, d) -> (b, s, d), new cache.

    cache: {"h": (b, d_rnn) f32, "conv": (b, w-1, d_rnn)} or None (training).
    """
    xc = x.astype(COMPUTE_DTYPE)
    gate = jax.nn.gelu(jnp.dot(xc, params["w_rnn_gate"].astype(COMPUTE_DTYPE)))
    u = jnp.dot(xc, params["w_rnn_in"].astype(COMPUTE_DTYPE))
    u = shard_act(u, "batch", None, "rnn")
    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = causal_conv1d(u, params["conv_w"].astype(u.dtype), conv_state)
    a, bx = _rglru_gates(params, u)
    h0 = cache["h"] if cache is not None else None
    h = rglru_scan(a, bx, h0)
    y = (gate.astype(jnp.float32) * h).astype(COMPUTE_DTYPE)
    out = jnp.dot(y, params["w_rnn_out"].astype(COMPUTE_DTYPE))
    new_cache = None
    if cache is not None:
        new_cache = {"h": h[:, -1], "conv": new_conv}
    return out, new_cache


def init_rglru_out(rng, d: int, d_rnn: int):
    return {"w_rnn_out": _normal(rng, (d_rnn, d), 1.0 / math.sqrt(d_rnn))}


def init_rglru_cache(batch: int, d_rnn: int, conv_width: int = 4):
    return {"h": jnp.zeros((batch, d_rnn), jnp.float32),
            "conv": jnp.zeros((batch, conv_width - 1, d_rnn), COMPUTE_DTYPE)}


def rglru_decode(params, x: Array, cache: dict):
    """Single-token step. x: (b, 1, d)."""
    return rglru_block(params, x, cache)


# ---------------------------------------------------------------------------
# mLSTM (matrix memory, chunkwise-parallel)
# ---------------------------------------------------------------------------

def init_mlstm(rng, d: int, n_heads: int, head_dim: int):
    ks = jax.random.split(rng, 3)
    std = 1.0 / math.sqrt(d)
    return {
        "wqkv_lstm": _normal(ks[0], (d, 3, n_heads, head_dim), std),
        "w_gates": _normal(ks[1], (d, 2, n_heads), std),
        "w_lstm_out": _normal(ks[2], (n_heads, head_dim, d),
                              1.0 / math.sqrt(n_heads * head_dim)),
    }


def init_mlstm_cache(batch: int, n_heads: int, head_dim: int):
    return {
        "C": jnp.zeros((batch, n_heads, head_dim, head_dim), jnp.float32),
        "n": jnp.zeros((batch, n_heads, head_dim), jnp.float32),
        "m": jnp.full((batch, n_heads), -1e30, jnp.float32),
    }


def _mlstm_qkv_gates(params, x: Array):
    xc = x.astype(COMPUTE_DTYPE)
    qkv = jnp.einsum("bsd,dthk->tbshk", xc, params["wqkv_lstm"].astype(COMPUTE_DTYPE))
    q, k, v = qkv[0], qkv[1], qkv[2]
    gates = jnp.einsum("bsd,dgh->gbsh", xc.astype(jnp.float32), params["w_gates"])
    i_raw, f_raw = gates[0], gates[1]             # (b, s, H)
    log_f = -jax.nn.softplus(-f_raw)              # log sigmoid
    log_i = i_raw                                 # exponential input gate
    dh = q.shape[-1]
    q = q / math.sqrt(dh)
    return q, k, v, log_i, log_f


def mlstm_chunkwise(params, x: Array, cache: Optional[dict] = None,
                    chunk: int = 128):
    """Chunkwise-parallel mLSTM. x: (b, s, d). Returns (out, new_cache)."""
    b, s, d = x.shape
    q, k, v, log_i, log_f = _mlstm_qkv_gates(params, x)
    H, dh = q.shape[2], q.shape[3]
    chunk = min(chunk, s)
    s_orig = s
    pad = (-s) % chunk
    if pad:  # identity-pad: f=1, i=0 so padded steps do not move the state
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, z4) for t in (q, k, v))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
        s += pad
    nc = s // chunk

    def resh(t):  # (b, s, H, ...) -> (nc, b, H, chunk, ...)
        t = t.reshape(b, nc, chunk, *t.shape[2:])
        return jnp.moveaxis(jnp.moveaxis(t, 1, 0), 3, 2)

    qc, kc, vc = resh(q), resh(k), resh(v)        # (nc, b, H, L, dh)
    lic = jnp.moveaxis(log_i.reshape(b, nc, chunk, H), (1, 3), (0, 2))  # (nc,b,H,L)
    lfc = jnp.moveaxis(log_f.reshape(b, nc, chunk, H), (1, 3), (0, 2))

    if cache is None:
        cache = init_mlstm_cache(b, H, dh)

    def body(carry, inp):
        C, n, m = carry                            # (b,H,dh,dh), (b,H,dh), (b,H)
        qi, ki, vi, li, lf = inp
        qi32, ki32, vi32 = (t.astype(jnp.float32) for t in (qi, ki, vi))
        bsum = jnp.cumsum(lf, axis=-1)             # (b,H,L) inclusive cumsum
        # per-position stabilizer: m_t = max(m_prev + bsum_t, max_{s<=t}(bsum_t - bsum_s + li_s))
        g = li - bsum                              # (b,H,L)
        gmax = jax.lax.cummax(g, axis=g.ndim - 1)
        m_t = jnp.maximum(m[..., None] + bsum, bsum + gmax)  # (b,H,L)
        # intra-chunk decay matrix D[t,s] = exp(bsum_t - bsum_s + li_s - m_t)
        Dlog = bsum[..., :, None] - bsum[..., None, :] + li[..., None, :]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        Dlog = jnp.where(mask, Dlog - m_t[..., :, None], -1e30)
        D = jnp.exp(Dlog)                          # (b,H,L,L)
        scores = jnp.einsum("bhld,bhmd->bhlm", qi32, ki32) * D
        num_intra = jnp.einsum("bhlm,bhmd->bhld", scores, vi32)
        den_intra = jnp.sum(scores, axis=-1)                    # (b,H,L)
        # inter-chunk: scale exp(m_prev + bsum_t - m_t)
        w_inter = jnp.exp(m[..., None] + bsum - m_t)            # (b,H,L)
        num_inter = jnp.einsum("bhld,bhdk->bhlk", qi32, C) * w_inter[..., None]
        den_inter = jnp.einsum("bhld,bhd->bhl", qi32, n) * w_inter
        num = num_intra + num_inter
        den = den_intra + den_inter
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # state update to end of chunk
        m_L = m_t[..., -1]
        wk = jnp.exp(bsum[..., -1:] - bsum + li - m_L[..., None])  # (b,H,L)
        C_new = (jnp.exp(m + bsum[..., -1] - m_L)[..., None, None] * C
                 + jnp.einsum("bhl,bhld,bhlk->bhdk", wk, ki32, vi32))
        n_new = (jnp.exp(m + bsum[..., -1] - m_L)[..., None] * n
                 + jnp.einsum("bhl,bhld->bhd", wk, ki32))
        return (C_new, n_new, m_L), h

    (C, n, m), hs = jax.lax.scan(
        body, (cache["C"], cache["n"], cache["m"]), (qc, kc, vc, lic, lfc))
    # hs: (nc, b, H, L, dh) -> (b, s, H, dh)
    h = jnp.moveaxis(hs, 0, 1).transpose(0, 2, 1, 3, 4).reshape(b, H, s, dh)
    h = jnp.moveaxis(h, 1, 2)
    if s != s_orig:
        h = h[:, :s_orig]
    out = jnp.einsum("bshk,hkd->bsd", h.astype(COMPUTE_DTYPE),
                     params["w_lstm_out"].astype(COMPUTE_DTYPE))
    return out, {"C": C, "n": n, "m": m}


def mlstm_decode(params, x: Array, cache: dict):
    """Single-step recurrent mLSTM. x: (b, 1, d)."""
    q, k, v, log_i, log_f = _mlstm_qkv_gates(params, x)
    q1, k1, v1 = (t[:, 0].astype(jnp.float32) for t in (q, k, v))  # (b,H,dh)
    li, lf = log_i[:, 0], log_f[:, 0]                               # (b,H)
    C, n, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(lf + m, li)
    wf = jnp.exp(lf + m - m_new)[..., None]
    wi = jnp.exp(li - m_new)[..., None]
    C_new = wf[..., None] * C + jnp.einsum("bhd,bhk->bhdk", wi * k1, v1)
    n_new = wf * n + wi * k1
    num = jnp.einsum("bhd,bhdk->bhk", q1, C_new)
    den = jnp.einsum("bhd,bhd->bh", q1, n_new)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    out = jnp.einsum("bhk,hkd->bd", h.astype(COMPUTE_DTYPE),
                     params["w_lstm_out"].astype(COMPUTE_DTYPE))
    return out[:, None, :], {"C": C_new, "n": n_new, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, true recurrence -> sequential scan)
# ---------------------------------------------------------------------------

def init_slstm(rng, d: int, n_heads: int, head_dim: int):
    ks = jax.random.split(rng, 3)
    std = 1.0 / math.sqrt(d)
    stdh = 1.0 / math.sqrt(head_dim)
    return {
        # input projections for z, i, f, o (4 gates), per head
        "w_slstm_in": _normal(ks[0], (d, 4, n_heads, head_dim), std),
        # recurrent (hidden-to-hidden) per head, block-diagonal
        "r_slstm": _normal(ks[1], (4, n_heads, head_dim, head_dim), stdh),
        "w_lstm_out": _normal(ks[2], (n_heads, head_dim, d),
                              1.0 / math.sqrt(n_heads * head_dim)),
    }


def init_slstm_cache(batch: int, n_heads: int, head_dim: int):
    z = jnp.zeros((batch, n_heads, head_dim), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, n_heads, head_dim), -1e30)}


def slstm_block(params, x: Array, cache: Optional[dict] = None):
    """Sequential sLSTM. x: (b, s, d) -> (b, s, d), cache."""
    b, s, d = x.shape
    H, dh = params["w_slstm_in"].shape[2], params["w_slstm_in"].shape[3]
    proj = jnp.einsum("bsd,dghk->bsghk", x.astype(jnp.float32),
                      params["w_slstm_in"])            # (b,s,4,H,dh)
    if cache is None:
        cache = init_slstm_cache(b, H, dh)

    R = params["r_slstm"]                              # (4,H,dh,dh)

    def step(carry, pr):
        c, n, h, m = carry                             # (b,H,dh)
        rec = jnp.einsum("bhk,ghkj->bghj", h, R)       # (b,4,H,dh)
        zr, ir, fr, orr = [pr[:, g] + rec[:, g] for g in range(4)]
        z = jnp.tanh(zr)
        o = jax.nn.sigmoid(orr)
        log_f = -jax.nn.softplus(-fr)
        m_new = jnp.maximum(log_f + m, ir)
        i = jnp.exp(ir - m_new)
        f = jnp.exp(log_f + m - m_new)
        c_new = f * c + i * z
        n_new = f * n + i
        h_new = o * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, h_new, m_new), h_new

    prs = jnp.moveaxis(proj, 1, 0)                     # (s,b,4,H,dh)
    # unroll: amortises per-step gradient all-reduces of the recurrent
    # weights under SPMD (XLA merges collectives within the unrolled body)
    (c, n, h, m), hs = jax.lax.scan(
        step, (cache["c"], cache["n"], cache["h"], cache["m"]), prs,
        unroll=8 if s >= 8 else 1)
    hs = jnp.moveaxis(hs, 0, 1)                        # (b,s,H,dh)
    out = jnp.einsum("bshk,hkd->bsd", hs.astype(COMPUTE_DTYPE),
                     params["w_lstm_out"].astype(COMPUTE_DTYPE))
    return out, {"c": c, "n": n, "h": h, "m": m}


def slstm_decode(params, x: Array, cache: dict):
    return slstm_block(params, x, cache)
