"""Unified LM covering all 10 assigned architecture families.

A model is a cycled ``pattern`` of block kinds over ``n_layers``:
  "attn"   — global causal attention (+RoPE, softcap optional)
  "local"  — sliding-window causal attention
  "rec"    — RG-LRU recurrent block (Griffin / RecurrentGemma)
  "mlstm"  — xLSTM matrix-memory block (chunkwise-parallel)
  "slstm"  — xLSTM scalar-memory block (sequential scan)
Each block is [norm -> mixer -> residual] + [norm -> MLP|MoE -> residual]
(pattern-uniform). Layers are grouped into scanned periods (lax.scan over the
stacked period params — O(1) HLO in depth) plus an unrolled remainder.

Encoder-decoder (whisper) wraps two stacks and adds cross-attention; VLM /
audio frontends are stubs supplying precomputed patch/frame embeddings.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_act
from repro.models import attention as attn
from repro.models import recurrent as rec
from repro.models.layers import (COMPUTE_DTYPE, apply_mlp, embed, init_embedding,
                                 init_layernorm, init_mlp, init_rmsnorm,
                                 init_unembed, layer_norm, rms_norm,
                                 sinusoidal_positions, softcap, unembed, _normal)
from repro.models.moe import apply_moe, init_moe

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    pattern: tuple = ("attn",)
    window: int = 4096
    mlp_kind: str = "swiglu"          # swiglu | geglu | gelu | none
    norm_kind: str = "rms"            # rms | ln
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    pos_kind: str = "rope"            # rope | sinusoidal | none
    rope_theta: float = 10000.0
    post_norm: bool = False
    embed_scale: bool = False
    enc_dec: bool = False
    n_enc_layers: int = 0
    frontend: str = "none"            # none | audio_stub | vision_stub
    n_prefix: int = 0
    d_rnn: int = 0
    conv_width: int = 4
    lstm_chunk: int = 128
    tie_embeddings: bool = True
    q_chunk: int = 512
    kv_chunk: int = 512
    banded_causal: bool = False
    remat: bool = True
    sub_quadratic: bool = False       # eligible for long_500k

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 (TP-divisible; Megatron practice).
        Pad logits are masked to -inf in the loss; labels never hit the pad."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    @property
    def rest_kinds(self) -> tuple:
        return self.pattern[: self.n_layers % self.period]

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    def layer_kinds(self) -> list:
        return [self.pattern[i % self.period] for i in range(self.n_layers)]


# ---------------------------------------------------------------------------
# Block init
# ---------------------------------------------------------------------------

def _init_norm(cfg):
    return init_rmsnorm(cfg.d_model) if cfg.norm_kind == "rms" else init_layernorm(cfg.d_model)


def _norm(cfg, p, x):
    return rms_norm(p, x) if cfg.norm_kind == "rms" else layer_norm(p, x)


def init_block(rng, cfg: ModelConfig, kind: str, cross: bool = False):
    ks = jax.random.split(rng, 8)
    p: dict = {"norm1": _init_norm(cfg)}
    if kind in ("attn", "local"):
        p["mixer"] = attn.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                         cfg.n_kv_heads, cfg.head_dim)
    elif kind == "rec":
        p["mixer"] = rec.init_rglru(ks[0], cfg.d_model, cfg.d_rnn, cfg.conv_width)
        p["mixer"].update(rec.init_rglru_out(ks[1], cfg.d_model, cfg.d_rnn))
    elif kind == "mlstm":
        p["mixer"] = rec.init_mlstm(ks[0], cfg.d_model, cfg.n_heads, cfg.head_dim)
    elif kind == "slstm":
        p["mixer"] = rec.init_slstm(ks[0], cfg.d_model, cfg.n_heads, cfg.head_dim)
    else:
        raise ValueError(f"unknown block kind {kind}")
    if cross:
        p["norm_cross"] = _init_norm(cfg)
        p["cross"] = attn.init_attention(ks[2], cfg.d_model, cfg.n_heads,
                                         cfg.n_kv_heads, cfg.head_dim)
    if cfg.mlp_kind != "none":
        p["norm2"] = _init_norm(cfg)
        if cfg.is_moe:
            p["moe"] = init_moe(ks[3], cfg.d_model, cfg.moe_d_ff, cfg.moe_experts)
        else:
            p["mlp"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp_kind)
    if cfg.post_norm:
        p["norm1_post"] = _init_norm(cfg)
        if cfg.mlp_kind != "none":
            p["norm2_post"] = _init_norm(cfg)
    return p


# ---------------------------------------------------------------------------
# Block apply — mode in {"train", "prefill", "decode"}
# ---------------------------------------------------------------------------

def apply_block(bp, x, cfg: ModelConfig, kind: str, mode: str,
                cache=None, enc_out=None, cross_cache=None):
    h = _norm(cfg, bp["norm1"], x)
    window = cfg.window if kind == "local" else 0
    new_cache = cache
    if kind in ("attn", "local"):
        use_rope = cfg.pos_kind == "rope"
        if mode == "train":
            mix = attn.attn_forward(
                bp["mixer"], h, n_kv=cfg.n_kv_heads, causal=True,
                window=window, rope_theta=cfg.rope_theta, use_rope=use_rope,
                cap=cfg.attn_softcap, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                banded_causal=cfg.banded_causal)
        elif mode == "encode":
            mix = attn.attn_forward(
                bp["mixer"], h, n_kv=cfg.n_kv_heads, causal=False,
                window=0, rope_theta=cfg.rope_theta, use_rope=use_rope,
                cap=cfg.attn_softcap, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        elif mode == "prefill":
            mix, new_cache = attn.attn_prefill(
                bp["mixer"], h, cache, n_kv=cfg.n_kv_heads, window=window,
                rope_theta=cfg.rope_theta, use_rope=use_rope,
                cap=cfg.attn_softcap, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        else:  # decode
            mix, new_cache = attn.attn_decode(
                bp["mixer"], h, cache, n_kv=cfg.n_kv_heads, window=window,
                rope_theta=cfg.rope_theta, use_rope=use_rope,
                cap=cfg.attn_softcap)
    elif kind == "rec":
        mix, new_cache = rec.rglru_block(bp["mixer"], h, cache)
    elif kind == "mlstm":
        mix, new_cache = rec.mlstm_chunkwise(bp["mixer"], h, cache,
                                             chunk=min(cfg.lstm_chunk, h.shape[1]))
    elif kind == "slstm":
        mix, new_cache = rec.slstm_block(bp["mixer"], h, cache)
    else:
        raise ValueError(kind)

    if cfg.post_norm:
        mix = _norm(cfg, bp["norm1_post"], mix)
    x = x + mix
    x = shard_act(x, "batch", None, None)

    if "cross" in bp:
        hc = _norm(cfg, bp["norm_cross"], x)
        if cross_cache is not None:
            ck, cv = cross_cache
        else:
            ck, cv = attn.cross_kv(bp["cross"], enc_out)
        x = x + attn.cross_attend(bp["cross"], hc, ck, cv,
                                  q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                  cap=cfg.attn_softcap)

    if cfg.mlp_kind != "none":
        h2 = _norm(cfg, bp["norm2"], x)
        if cfg.is_moe:
            ff = apply_moe(bp["moe"], h2, top_k=cfg.moe_top_k,
                           capacity_factor=cfg.moe_capacity_factor)
        else:
            ff = apply_mlp(bp["mlp"], h2, cfg.mlp_kind)
        if cfg.post_norm:
            ff = _norm(cfg, bp["norm2_post"], ff)
        x = x + ff
        x = shard_act(x, "batch", None, None)
    return x, new_cache


# ---------------------------------------------------------------------------
# Stacks (scan over periods + unrolled remainder)
# ---------------------------------------------------------------------------

def init_stack(rng, cfg: ModelConfig, n_layers: int, pattern: tuple,
               cross: bool = False):
    period = len(pattern)
    n_periods = n_layers // period
    rest = pattern[: n_layers % period]
    keys = jax.random.split(rng, n_periods * period + len(rest))

    def one_period(pk):
        return [init_block(k, cfg, kind, cross)
                for k, kind in zip(pk, pattern)]

    periods = [one_period(keys[i * period:(i + 1) * period])
               for i in range(n_periods)]
    scan_params = jax.tree.map(lambda *xs: jnp.stack(xs), *periods) \
        if n_periods > 0 else []
    rest_params = [init_block(keys[n_periods * period + i], cfg, kind, cross)
                   for i, kind in enumerate(rest)]
    return {"scan": scan_params, "rest": rest_params}


def apply_stack(sp, x, cfg: ModelConfig, pattern: tuple, mode: str,
                caches=None, enc_out=None, cross_caches=None):
    """caches: {"scan": stacked per-slot caches, "rest": list} or None."""
    has_cache = caches is not None
    has_cross = cross_caches is not None

    block_fns = {}
    for kind in set(pattern):
        def mk(kind):
            def fn(bp, x, c, cc):
                return apply_block(bp, x, cfg, kind, mode, c, enc_out, cc)
            return fn
        f = mk(kind)
        if cfg.remat and mode == "train":
            # inner level of the nested (2-level) remat: during a period's
            # backward recompute, each block re-saves only its input and is
            # re-materialised one at a time
            f = jax.checkpoint(f, prevent_cse=False)
        block_fns[kind] = f

    def period_body(carry, inp):
        x = carry
        pp = inp[0]
        pc = inp[1] if has_cache else None
        pcc = inp[2] if has_cross else None
        new_pc = []
        for j, kind in enumerate(pattern):
            c = pc[j] if has_cache else None
            cc = pcc[j] if has_cross else None
            x, nc = block_fns[kind](pp[j], x, c, cc)
            new_pc.append(nc)
        return x, (new_pc if has_cache else 0)

    body = period_body
    if cfg.remat and mode == "train":
        # outer level of the nested remat: the layer scan stores ONE residual
        # (the period input) per period; blocks recompute on the way back
        body = jax.checkpoint(period_body, prevent_cse=False)

    if sp["scan"]:
        xs = [sp["scan"]]
        if has_cache:
            xs.append(caches["scan"])
        if has_cross:
            xs.append(cross_caches["scan"])
        x, new_scan = jax.lax.scan(body, x, tuple(xs))
    else:
        new_scan = []

    new_rest = []
    rest = pattern[: len(sp["rest"])]
    for i, kind in enumerate(rest):
        c = caches["rest"][i] if has_cache else None
        cc = cross_caches["rest"][i] if has_cross else None
        x, nc = block_fns[kind](sp["rest"][i], x, c, cc)
        new_rest.append(nc)

    new_caches = {"scan": new_scan, "rest": new_rest} if has_cache else None
    return x, new_caches


def cfg_n_periods(sp) -> int:
    leaves = jax.tree.leaves(sp["scan"])
    return leaves[0].shape[0] if leaves else 0


def _dummy(n: int):
    return jnp.zeros((n,), jnp.int32)


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def init_params(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 6)
    params: dict = {"embed": init_embedding(ks[0], cfg.padded_vocab, cfg.d_model)}
    if cfg.enc_dec:
        params["encoder"] = init_stack(ks[1], cfg, cfg.n_enc_layers, ("attn",))
        params["enc_norm"] = _init_norm(cfg)
        params["decoder"] = init_stack(ks[2], cfg, cfg.n_layers, cfg.pattern,
                                       cross=True)
    else:
        params["decoder"] = init_stack(ks[2], cfg, cfg.n_layers, cfg.pattern)
    params["final_norm"] = _init_norm(cfg)
    if not cfg.tie_embeddings:
        params["unembed"] = init_unembed(ks[3], cfg.d_model, cfg.padded_vocab)
    return params


def param_count(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _embed_in(params, cfg, tokens):
    x = embed(params["embed"], tokens)
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    if cfg.pos_kind == "sinusoidal":
        pe = sinusoidal_positions(tokens.shape[1], cfg.d_model)
        x = x + pe[None].astype(x.dtype)
    return shard_act(x, "batch", None, None)


def _logits(params, cfg, x):
    x = _norm(cfg, params["final_norm"], x)
    tied = params["embed"]["embedding"] if cfg.tie_embeddings else None
    lg = unembed(params.get("unembed", {}), x, tied_embedding=tied)
    lg = softcap(lg, cfg.final_softcap)
    return shard_act(lg, "batch", None, "vocab")


def encode(params, cfg: ModelConfig, frames: Array) -> Array:
    """Whisper encoder over precomputed frame embeddings (conv stub)."""
    x = frames.astype(COMPUTE_DTYPE)
    if cfg.pos_kind == "sinusoidal":
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model)[None].astype(x.dtype)
    x = shard_act(x, "batch", None, None)
    x, _ = apply_stack(params["encoder"], x, cfg, ("attn",), "encode")
    return _norm(cfg, params["enc_norm"], x)


def forward_hidden(params, cfg: ModelConfig, batch: dict) -> Array:
    """Teacher-forced full-sequence final hidden states (pre-unembed)."""
    tokens = batch["tokens"]
    if cfg.enc_dec:
        enc_out = encode(params, cfg, batch["frames"])
        x = _embed_in(params, cfg, tokens)
        x, _ = apply_stack(params["decoder"], x, cfg, cfg.pattern, "train",
                           enc_out=enc_out)
    else:
        x = _embed_in(params, cfg, tokens)
        if cfg.frontend == "vision_stub":
            px = batch["patches"].astype(COMPUTE_DTYPE)
            x = jnp.concatenate([px, x], axis=1)
        elif cfg.frontend == "audio_stub" and "frames" in batch:
            fx = batch["frames"].astype(COMPUTE_DTYPE)
            x = jnp.concatenate([fx, x], axis=1)
        x = shard_act(x, "batch", None, None)
        x, _ = apply_stack(params["decoder"], x, cfg, cfg.pattern, "train")
    return x


def forward(params, cfg: ModelConfig, batch: dict) -> Array:
    """Teacher-forced full-sequence logits (training path)."""
    return _logits(params, cfg, forward_hidden(params, cfg, batch))


# ---------------------------------------------------------------------------
# Caches / serving
# ---------------------------------------------------------------------------

def _block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    if kind == "attn":
        return attn.init_kv_cache(batch, cfg.n_kv_heads, cfg.head_dim, max_len)
    if kind == "local":
        return attn.init_kv_cache(batch, cfg.n_kv_heads, cfg.head_dim, max_len,
                                  window=cfg.window)
    if kind == "rec":
        return rec.init_rglru_cache(batch, cfg.d_rnn, cfg.conv_width)
    if kind == "mlstm":
        return rec.init_mlstm_cache(batch, cfg.n_heads, cfg.head_dim)
    if kind == "slstm":
        return rec.init_slstm_cache(batch, cfg.n_heads, cfg.head_dim)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    def stack_caches(kind):
        per = [_block_cache(cfg, kind, batch, max_len)
               for _ in range(cfg.n_periods)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    scan_c = [stack_caches(kind) for kind in cfg.pattern] if cfg.n_periods else []
    rest_c = [_block_cache(cfg, kind, batch, max_len) for kind in cfg.rest_kinds]
    return {"scan": scan_c, "rest": rest_c}


def init_cross_cache(cfg: ModelConfig, batch: int, enc_len: int):
    def one():
        shape = (batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
        return (jnp.zeros(shape, COMPUTE_DTYPE), jnp.zeros(shape, COMPUTE_DTYPE))

    per = [jax.tree.map(lambda *xs: jnp.stack(xs),
                        *[one() for _ in range(cfg.n_periods)])
           for _ in cfg.pattern]
    rest = [one() for _ in cfg.rest_kinds]
    return {"scan": per, "rest": rest}


def build_cross_cache(params, cfg: ModelConfig, enc_out: Array):
    """Precompute per-layer cross K/V from encoder output."""
    dp = params["decoder"]

    def plain_kv(cp):  # shard_act-free (vmap-safe) version of attn.cross_kv
        xc = enc_out.astype(COMPUTE_DTYPE)
        k = jnp.einsum("bsd,dhk->bshk", xc, cp["wk"].astype(COMPUTE_DTYPE))
        v = jnp.einsum("bsd,dhk->bshk", xc, cp["wv"].astype(COMPUTE_DTYPE))
        return k, v

    def per_slot(slot_params):
        return jax.vmap(lambda pp: plain_kv(pp["cross"]))(slot_params)

    scan_cc = [per_slot(dp["scan"][j])
               for j in range(len(cfg.pattern))] if dp["scan"] else []
    rest_cc = [plain_kv(bp["cross"]) for bp in dp["rest"]]
    return {"scan": scan_cc, "rest": rest_cc}


def prefill(params, cfg: ModelConfig, batch: dict, max_len: int):
    """Process the prompt; returns (last-position logits, cache)."""
    tokens = batch["tokens"]
    b = tokens.shape[0]
    cache = init_cache(cfg, b, max_len)
    cross_caches = None
    if cfg.enc_dec:
        enc_out = encode(params, cfg, batch["frames"])
        cross_caches = build_cross_cache(params, cfg, enc_out)
        x = _embed_in(params, cfg, tokens)
    else:
        x = _embed_in(params, cfg, tokens)
        if cfg.frontend == "vision_stub":
            x = jnp.concatenate([batch["patches"].astype(COMPUTE_DTYPE), x], axis=1)
        elif cfg.frontend == "audio_stub" and "frames" in batch:
            x = jnp.concatenate([batch["frames"].astype(COMPUTE_DTYPE), x], axis=1)
    x, cache = apply_stack(params["decoder"], x, cfg, cfg.pattern, "prefill",
                           caches=cache, cross_caches=cross_caches)
    logits = _logits(params, cfg, x[:, -1:])
    return logits, {"self": cache, "cross": cross_caches}


def decode_step(params, cfg: ModelConfig, token: Array, cache: dict):
    """token: (b, 1) -> (logits (b, 1, V), new cache)."""
    x = _embed_in_decode(params, cfg, token, cache)
    x, new_self = apply_stack(params["decoder"], x, cfg, cfg.pattern, "decode",
                              caches=cache["self"],
                              cross_caches=cache.get("cross"))
    logits = _logits(params, cfg, x)
    return logits, {"self": new_self, "cross": cache.get("cross")}


def _embed_in_decode(params, cfg, token, cache):
    x = embed(params["embed"], token)
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    if cfg.pos_kind == "sinusoidal":
        pos = _cache_pos(cfg, cache)
        pe = sinusoidal_positions(1, cfg.d_model)  # placeholder; use pos below
        div = jnp.exp(jnp.arange(0, cfg.d_model, 2, dtype=jnp.float32)
                      * (-math.log(10000.0) / cfg.d_model))
        ang = pos.astype(jnp.float32) * div
        pe = jnp.zeros((cfg.d_model,), jnp.float32)
        pe = pe.at[0::2].set(jnp.sin(ang)).at[1::2].set(jnp.cos(ang))
        x = x + pe[None, None, :].astype(x.dtype)
    return shard_act(x, "batch", None, None)


def _cache_pos(cfg, cache):
    """Current decode position from the first attention cache found."""
    sc = cache["self"]
    for j, kind in enumerate(cfg.pattern):
        if kind in ("attn", "local") and sc["scan"]:
            return sc["scan"][j]["pos"][0]
    for i, kind in enumerate(cfg.rest_kinds):
        if kind in ("attn", "local"):
            return sc["rest"][i]["pos"]
    return jnp.zeros((), jnp.int32)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(params, cfg: ModelConfig, batch: dict,
            seq_chunk: int = 512) -> tuple:
    """Next-token cross-entropy with a sequence-chunked, rematerialised
    unembedding: the (b, s, V) logits tensor never exists — each chunk's
    logits are computed, reduced to (logz, gold) scalars-per-token, and
    recomputed in the backward pass (jax.checkpoint). Cuts the loss-head
    peak memory by s/seq_chunk (~60x for the 262k-vocab archs).

    Prefix positions (patches/frames for decoder-only frontends) are
    excluded via the label mask."""
    x = forward_hidden(params, cfg, batch)          # (b, s_total, d)
    tokens = batch["tokens"]
    n_prefix = x.shape[1] - tokens.shape[1]
    x = x[:, n_prefix:]
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
    mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
    if "loss_mask" in batch:
        mask = mask * batch["loss_mask"]

    b, s, d = x.shape
    seq_chunk = min(seq_chunk, s)
    pad = (-s) % seq_chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nch = (s + pad) // seq_chunk

    def resh(t):
        t = t.reshape(b, nch, seq_chunk, *t.shape[2:])
        return jnp.moveaxis(t, 1, 0)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_nll(xc, lc, mc):
        lg = _logits(params, cfg, xc).astype(jnp.float32)
        if cfg.padded_vocab != cfg.vocab_size:
            valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
            lg = jnp.where(valid, lg, -1e30)
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, lc[..., None], axis=-1)[..., 0]
        return jnp.sum((logz - gold) * mc), jnp.sum(logz * mc)

    def body(carry, inp):
        nll_sum, logz_sum = carry
        nll_c, logz_c = chunk_nll(*inp)
        return (nll_sum + nll_c, logz_sum + logz_c), None

    (nll, logz_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (resh(x), resh(labels), resh(mask)))
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = nll / denom
    metrics = {"loss": loss, "ppl_log": loss,
               "tokens": denom, "logz_mean": logz_sum / denom}
    return loss, metrics
