"""Attention: GQA + RoPE + sliding-window + softcap + caches, flash-style.

Memory discipline: no (S x S) score matrix is ever materialised. Prefill and
training run a two-level chunked online-softmax (outer scan over query chunks,
inner scan over KV chunks) — the pure-JAX flash-attention pattern, which keeps
the peak live intermediate at (b, heads, q_chunk, kv_chunk).

Sliding-window layers use a *banded* inner loop: each query chunk slices only
the (window + q_chunk) span of KV it can see, so window attention lowers to
O(S*W) FLOPs, not O(S^2) masked.

Causal full attention is masked-full by default (2x score FLOPs — honest
baseline; see EXPERIMENTS.md §Perf for the banded variant).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.compat import shard_map

from repro.distributed.sharding import shard_act
from repro.models.layers import COMPUTE_DTYPE, _normal, apply_rope, softcap

Array = jax.Array
NEG = -1e30  # mask value (avoid nan from -inf - -inf)


def init_attention(rng, d: int, n_heads: int, n_kv: int, head_dim: int):
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    std = 1.0 / math.sqrt(d)
    return {
        "wq": _normal(k1, (d, n_heads, head_dim), std),
        "wk": _normal(k2, (d, n_kv, head_dim), std),
        "wv": _normal(k3, (d, n_kv, head_dim), std),
        "wo": _normal(k4, (n_heads, head_dim, d), 1.0 / math.sqrt(n_heads * head_dim)),
    }


def _qkv(params, x: Array, n_kv: int):
    xc = x.astype(COMPUTE_DTYPE)
    q = jnp.einsum("bsd,dhk->bshk", xc, params["wq"].astype(COMPUTE_DTYPE))
    k = jnp.einsum("bsd,dhk->bshk", xc, params["wk"].astype(COMPUTE_DTYPE))
    v = jnp.einsum("bsd,dhk->bshk", xc, params["wv"].astype(COMPUTE_DTYPE))
    q = shard_act(q, "batch", None, "heads", "head_dim")
    k = shard_act(k, "batch", None, "kv_heads", "head_dim")
    v = shard_act(v, "batch", None, "kv_heads", "head_dim")
    return q, k, v


def _out(params, o: Array) -> Array:
    return jnp.einsum("bshk,hkd->bsd", o.astype(COMPUTE_DTYPE),
                      params["wo"].astype(COMPUTE_DTYPE))


# ---------------------------------------------------------------------------
# Chunked online-softmax core
# ---------------------------------------------------------------------------

def _chunk_scores(q, ks, scale, cap):
    """q: (b, qc, KV, g, dh); ks: (b, kc, KV, dh) -> (b, KV, g, qc, kc) f32."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(COMPUTE_DTYPE),
                   ks.astype(COMPUTE_DTYPE),
                   preferred_element_type=jnp.float32)
    return softcap(s * scale, cap)


def _online_block(q, k, v, q_pos, kv_pos, *, scale, cap, causal, window,
                  kv_chunk):
    """Attend q chunk over the whole given k/v with an inner online scan.

    q: (b, qc, KV, g, dh); k, v: (b, skv, KV, dh);
    q_pos: (qc,) absolute; kv_pos: (skv,) absolute (-1 = invalid slot).
    Returns (b, qc, KV, g, dh) f32 output.
    """
    b, qc, KV, g, dh = q.shape
    skv = k.shape[1]
    nkc = skv // kv_chunk

    def body(carry, idx):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, idx * kv_chunk, kv_chunk, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, idx * kv_chunk, kv_chunk, 1)
        kp = jax.lax.dynamic_slice_in_dim(kv_pos, idx * kv_chunk, kv_chunk, 0)
        s = _chunk_scores(q, ks, scale, cap)           # (b, KV, g, qc, kc)
        ok = kp[None, :] >= 0
        if causal:
            ok = ok & (q_pos[:, None] >= kp[None, :])
        if window > 0:
            ok = ok & (q_pos[:, None] - kp[None, :] < window)
        s = jnp.where(ok[None, None, None, :, :], s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        upd = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(COMPUTE_DTYPE),
                         vs.astype(COMPUTE_DTYPE),
                         preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + upd
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, KV, g, qc), NEG, jnp.float32)
    l0 = jnp.zeros((b, KV, g, qc), jnp.float32)
    a0 = jnp.zeros((b, KV, g, qc, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nkc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.transpose(out, (0, 3, 1, 2, 4))          # (b, qc, KV, g, dh)


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      q_offset=0, scale: Optional[float] = None,
                      cap: Optional[float] = None, q_chunk: int = 512,
                      kv_chunk: int = 512, banded_causal: bool = False,
                      _no_seq_shard: bool = False) -> Array:
    """q: (b, sq, H, dh); k, v: (b, skv, KV, dh). Returns (b, sq, H, dh).

    ``window`` > 0 restricts attention to the last ``window`` positions and
    activates the banded KV slicing path (O(S*W) FLOPs).
    ``banded_causal`` activates per-q-chunk KV truncation for causal full
    attention (FLOP-exact, larger HLO; used by the §Perf variants).

    Sequence-parallel core: when the active AxisRules set
    ``attn_core_seq_shard`` (archs whose head count does not divide the TP
    axis), the core runs under shard_map with queries sequence-sharded over
    that axis and K/V replicated (cheap for GQA's few KV heads) — the exact
    context-parallel formulation, FLOPs split across the axis.
    """
    b, sq, H, dh = q.shape
    if not _no_seq_shard:
        from repro.distributed.sharding import current_rules
        from jax.sharding import PartitionSpec as P
        r = current_rules()
        ax = r.rules.get("attn_core_seq_shard") if (r and r.mesh) else None
        if ax is not None and not banded_causal:
            n_ax = dict(zip(r.mesh.axis_names, r.mesh.devices.shape))[ax]
            if sq > 1 and sq % n_ax == 0:
                dp = r.rules.get("batch")
                s_loc = sq // n_ax

                def local(qs, ks, vs):
                    idx = jax.lax.axis_index(ax)
                    return chunked_attention(
                        qs, ks, vs, causal=causal, window=window,
                        q_offset=q_offset + idx * s_loc, scale=scale, cap=cap,
                        q_chunk=min(q_chunk, s_loc), kv_chunk=kv_chunk,
                        _no_seq_shard=True)

                return shard_map(
                    local, mesh=r.mesh,
                    in_specs=(P(dp, ax), P(dp), P(dp)),
                    out_specs=P(dp, ax), check_vma=False)(q, k, v)
    KV = k.shape[2]
    g = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    q = q.reshape(b, sq, KV, g, dh)

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, k.shape[1])
    # pad q/kv to chunk multiples (padded KV slots carry kv_pos = -1 -> masked)
    sq_orig, skv_orig = sq, k.shape[1]
    q_pad = (-sq) % q_chunk
    kv_pad = (-k.shape[1]) % kv_chunk
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0), (0, 0)))
        sq += q_pad
    kv_pos = jnp.arange(skv_orig, dtype=jnp.int32)
    if kv_pad:
        k = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        kv_pos = jnp.concatenate([kv_pos, jnp.full((kv_pad,), -1, jnp.int32)])
    nqc = sq // q_chunk

    if window > 0 and causal:
        # banded: each q chunk sees a fixed (window + q_chunk) KV span
        span = window + q_chunk
        span = min(int(math.ceil(span / kv_chunk)) * kv_chunk, k.shape[1])

        def q_body(_, i):
            qs = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, 1)
            q_pos = q_offset + i * q_chunk + jnp.arange(q_chunk, dtype=jnp.int32)
            start = jnp.clip(q_offset + i * q_chunk + q_chunk - span, 0,
                             k.shape[1] - span)
            ks = jax.lax.dynamic_slice_in_dim(k, start, span, 1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, span, 1)
            kp = start + jnp.arange(span, dtype=jnp.int32)
            o = _online_block(qs, ks, vs, q_pos, kp, scale=scale, cap=cap,
                              causal=True, window=window, kv_chunk=kv_chunk)
            return None, o

        _, outs = jax.lax.scan(q_body, None, jnp.arange(nqc))
    elif causal and banded_causal:
        # FLOP-exact causal: python loop, q chunk i scans only chunks <= i
        outs_list = []
        for i in range(nqc):
            qs = q[:, i * q_chunk:(i + 1) * q_chunk]
            q_pos = q_offset + i * q_chunk + jnp.arange(q_chunk, dtype=jnp.int32)
            hi_chunk = min((q_offset + (i + 1) * q_chunk + kv_chunk - 1) // kv_chunk,
                           k.shape[1] // kv_chunk)
            hi = max(hi_chunk * kv_chunk, kv_chunk)
            o = _online_block(qs, k[:, :hi], v[:, :hi], q_pos, kv_pos[:hi],
                              scale=scale, cap=cap, causal=True, window=0,
                              kv_chunk=kv_chunk)
            outs_list.append(o)
        outs = jnp.stack(outs_list, axis=0)
    else:
        def q_body(_, i):
            qs = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, 1)
            q_pos = q_offset + i * q_chunk + jnp.arange(q_chunk, dtype=jnp.int32)
            o = _online_block(qs, k, v, q_pos, kv_pos, scale=scale, cap=cap,
                              causal=causal, window=window, kv_chunk=kv_chunk)
            return None, o

        _, outs = jax.lax.scan(q_body, None, jnp.arange(nqc))

    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, H, dh)
    if sq != sq_orig:
        out = out[:, :sq_orig]
    return out.astype(COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, n_kv: int, head_dim: int, max_len: int,
                  window: int = 0, dtype=COMPUTE_DTYPE):
    """window > 0 -> rolling buffer of size window (padded to 128)."""
    size = min(max_len, window) if window > 0 else max_len
    size = max(128, ((size + 127) // 128) * 128)
    size = min(size, max_len) if window == 0 else size
    return {
        "k": jnp.zeros((batch, size, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, size, n_kv, head_dim), dtype),
        "slot_pos": jnp.full((size,), -1, jnp.int32),  # absolute pos per slot
        "pos": jnp.zeros((), jnp.int32),               # next position
    }


def cache_update_prefill(cache, k, v):
    """Write a full prefill of length s at positions [0, s)."""
    s = k.shape[1]
    size = cache["k"].shape[1]
    if s >= size:  # keep the last `size` positions (rolling window case)
        ks, vs = k[:, s - size:], v[:, s - size:]
        pos = jnp.arange(s - size, s, dtype=jnp.int32)
        # store at slot = pos % size so decode writes continue seamlessly
        slots = pos % size
        order = jnp.argsort(slots)
        new = {
            "k": ks[:, order], "v": vs[:, order],
            "slot_pos": pos[order], "pos": jnp.asarray(s, jnp.int32),
        }
        return new
    nk = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, 1)
    nv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, 1)
    sp = cache["slot_pos"].at[:s].set(jnp.arange(s, dtype=jnp.int32))
    return {"k": nk, "v": nv, "slot_pos": sp, "pos": jnp.asarray(s, jnp.int32)}


def cache_update_decode(cache, k1, v1):
    """Append one position (k1, v1: (b, 1, KV, dh)) at slot pos % size."""
    size = cache["k"].shape[1]
    pos = cache["pos"]
    slot = pos % size
    nk = jax.lax.dynamic_update_slice_in_dim(cache["k"], k1.astype(cache["k"].dtype), slot, 1)
    nv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v1.astype(cache["v"].dtype), slot, 1)
    sp = jax.lax.dynamic_update_slice_in_dim(cache["slot_pos"],
                                             pos[None].astype(jnp.int32), slot, 0)
    return {"k": nk, "v": nv, "slot_pos": sp, "pos": pos + 1}


def decode_attend(q, cache, *, window: int = 0, scale=None, cap=None) -> Array:
    """Single-step attention over the cache. q: (b, 1, H, dh)."""
    b, sq, H, dh = q.shape
    k, v, slot_pos = cache["k"], cache["v"], cache["slot_pos"]
    KV = k.shape[2]
    g = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    pos = cache["pos"] - 1  # position of the query token
    qh = q.reshape(b, sq, KV, g, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh.astype(COMPUTE_DTYPE),
                   k.astype(COMPUTE_DTYPE), preferred_element_type=jnp.float32)
    s = softcap(s * scale, cap)
    ok = (slot_pos >= 0) & (slot_pos <= pos)
    if window > 0:
        ok = ok & (pos - slot_pos < window)
    s = jnp.where(ok[None, None, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(COMPUTE_DTYPE),
                   v.astype(COMPUTE_DTYPE))
    return o.reshape(b, sq, H, dh)


# ---------------------------------------------------------------------------
# Full attention blocks (train / prefill / decode)
# ---------------------------------------------------------------------------

def attn_forward(params, x, *, n_kv: int, causal: bool, window: int = 0,
                 positions=None, rope_theta: float = 10000.0,
                 use_rope: bool = True, cap=None, q_chunk=512, kv_chunk=512,
                 banded_causal: bool = False):
    """Training/encoding forward, no cache. x: (b, s, d)."""
    q, k, v = _qkv(params, x, n_kv)
    if use_rope:
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                         x.shape[:2])
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    o = chunked_attention(q, k, v, causal=causal, window=window, cap=cap,
                          q_chunk=q_chunk, kv_chunk=kv_chunk,
                          banded_causal=banded_causal)
    return _out(params, o)


def attn_prefill(params, x, cache, *, n_kv: int, window: int = 0,
                 rope_theta: float = 10000.0, use_rope: bool = True,
                 cap=None, q_chunk=512, kv_chunk=512):
    q, k, v = _qkv(params, x, n_kv)
    if use_rope:
        positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                     x.shape[:2])
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    o = chunked_attention(q, k, v, causal=True, window=window, cap=cap,
                          q_chunk=q_chunk, kv_chunk=kv_chunk)
    new_cache = cache_update_prefill(cache, k, v)
    return _out(params, o), new_cache


def attn_decode(params, x, cache, *, n_kv: int, window: int = 0,
                rope_theta: float = 10000.0, use_rope: bool = True, cap=None):
    """x: (b, 1, d) single new token."""
    q, k, v = _qkv(params, x, n_kv)
    if use_rope:
        pos = jnp.broadcast_to(cache["pos"][None, None], (x.shape[0], 1))
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    cache = cache_update_decode(cache, k, v)
    o = decode_attend(q, cache, window=window, cap=cap)
    return _out(params, o), cache


# ---------------------------------------------------------------------------
# Cross attention (enc-dec)
# ---------------------------------------------------------------------------

def cross_kv(params, enc_out):
    xc = enc_out.astype(COMPUTE_DTYPE)
    k = jnp.einsum("bsd,dhk->bshk", xc, params["wk"].astype(COMPUTE_DTYPE))
    v = jnp.einsum("bsd,dhk->bshk", xc, params["wv"].astype(COMPUTE_DTYPE))
    return shard_act(k, "batch", "kv_seq", "kv_heads", None), \
        shard_act(v, "batch", "kv_seq", "kv_heads", None)


def cross_attend(params, x, k, v, *, q_chunk=512, kv_chunk=512, cap=None):
    xc = x.astype(COMPUTE_DTYPE)
    q = jnp.einsum("bsd,dhk->bshk", xc, params["wq"].astype(COMPUTE_DTYPE))
    o = chunked_attention(q, k, v, causal=False, cap=cap,
                          q_chunk=q_chunk, kv_chunk=kv_chunk)
    return _out(params, o)
