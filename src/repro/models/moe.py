"""Mixture-of-Experts FFN: top-k routing + grouped dispatch + EP sharding.

TPU adaptation — the GShard/MaxText grouped formulation: tokens are reshaped
to (G, T/G, d) with the group dim G aligned to the data-parallel sharding, so
capacity accounting, the position cumsum and the dispatch scatter are all
LOCAL to a data shard (no cross-shard scatter -> no all-reduce of the
dispatch buffer, the failure mode of naive global dispatch). Expert FFNs run
as one batched einsum over (G, E, C, d) with E sharded over 'model' (EP);
only the combine crosses the model axis.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_act, current_rules
from repro.models.layers import COMPUTE_DTYPE, _normal

Array = jax.Array


def init_moe(rng, d: int, d_ff: int, n_experts: int):
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    std_in, std_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(d_ff)
    return {
        "w_router": _normal(k1, (d, n_experts), std_in),
        "we_in": _normal(k2, (n_experts, d, d_ff), std_in),
        "we_gate": _normal(k3, (n_experts, d, d_ff), std_in),
        "we_out": _normal(k4, (n_experts, d_ff, d), std_out),
    }


def _dp_groups(batch: int) -> int:
    """Number of dispatch groups = data-parallel degree (if it divides b)."""
    r = current_rules()
    if r is None or r.mesh is None:
        return 1
    ax = r.rules.get("batch")
    if ax is None:
        return 1
    axes = ax if isinstance(ax, tuple) else (ax,)
    sizes = dict(zip(r.mesh.axis_names, r.mesh.devices.shape))
    g = 1
    for a in axes:
        g *= sizes.get(a, 1)
    return g if (g > 1 and batch % g == 0) else 1


def apply_moe(params, x: Array, *, top_k: int, capacity_factor: float = 1.25,
              return_aux: bool = False):
    """x: (b, s, d) -> (b, s, d). Dropped tokens pass through the residual."""
    b, s, d = x.shape
    n_experts = params["w_router"].shape[-1]
    groups = _dp_groups(b)
    tokens = b * s
    t_loc = tokens // groups
    xt = x.reshape(groups, t_loc, d)
    xt = shard_act(xt, "batch", None, None)

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        params["w_router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, experts = jax.lax.top_k(probs, top_k)          # (G, T_loc, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    capacity = int(capacity_factor * t_loc * top_k / n_experts)
    capacity = max(8, min(capacity, t_loc))

    flat_e = experts.reshape(groups, t_loc * top_k)           # (G, TK)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) - onehot                 # per-group!
    pos_flat = jnp.sum(pos * onehot, axis=-1)                 # (G, TK)
    keep = pos_flat < capacity
    safe_pos = jnp.where(keep, pos_flat, 0)
    safe_e = jnp.where(keep, flat_e, 0)

    tok_ids = jnp.repeat(jnp.arange(t_loc), top_k)            # (TK,)
    contrib = jnp.where(keep[..., None],
                        xt[:, tok_ids].astype(COMPUTE_DTYPE), 0.0)

    def scatter_one(e_g, p_g, c_g):
        buf = jnp.zeros((n_experts, capacity, d), COMPUTE_DTYPE)
        return buf.at[e_g, p_g].add(c_g, mode="drop")

    buf = jax.vmap(scatter_one)(safe_e, safe_pos, contrib)    # (G, E, C, d)
    buf = shard_act(buf, "batch", "experts", None, None)

    h = jnp.einsum("gecd,edf->gecf", buf, params["we_in"].astype(COMPUTE_DTYPE))
    g = jnp.einsum("gecd,edf->gecf", buf, params["we_gate"].astype(COMPUTE_DTYPE))
    h = jax.nn.silu(g) * h
    out_buf = jnp.einsum("gecf,efd->gecd", h,
                         params["we_out"].astype(COMPUTE_DTYPE))
    out_buf = shard_act(out_buf, "batch", "experts", None, None)

    def gather_one(ob, e_g, p_g):
        return ob[e_g, p_g]                                   # (TK, d)

    gathered = jax.vmap(gather_one)(out_buf, safe_e, safe_pos)
    weighted = gathered * (gate_vals.reshape(groups, -1, 1)
                           * keep[..., None])

    def combine_one(w_g):
        return jnp.zeros((t_loc, d), COMPUTE_DTYPE).at[tok_ids].add(
            w_g.astype(COMPUTE_DTYPE), mode="drop")

    y = jax.vmap(combine_one)(weighted)                       # (G, T_loc, d)
    y = shard_act(y, "batch", None, None)
    y = y.reshape(b, s, d)

    if return_aux:
        me = jnp.mean(probs.reshape(-1, n_experts), axis=0)
        ce = jnp.mean(jax.nn.one_hot(experts[..., 0].reshape(-1), n_experts,
                                     dtype=jnp.float32), axis=0)
        aux = n_experts * jnp.sum(me * ce)
        return y, aux
    return y
