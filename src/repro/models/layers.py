"""Building-block layers (pure functions over param dicts).

Everything is functional: ``init_*`` returns a param dict; ``apply``-style
functions are pure. Compute dtype is bf16 (cast at entry of each matmul),
params and reductions stay f32 — the standard large-scale recipe.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array
COMPUTE_DTYPE = jnp.bfloat16


def _normal(rng, shape, std):
    return (std * jax.random.normal(rng, shape, jnp.float32))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.zeros((d,), jnp.float32)}


def rms_norm(params, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + params["scale"])
    return y.astype(x.dtype)


def init_layernorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layer_norm(params, x: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(rng, vocab: int, d: int):
    return {"embedding": _normal(rng, (vocab, d), 1.0 / math.sqrt(d))}


def embed(params, tokens: Array) -> Array:
    return params["embedding"][tokens].astype(COMPUTE_DTYPE)


def unembed(params, x: Array, tied_embedding: Optional[Array] = None) -> Array:
    w = tied_embedding.T if tied_embedding is not None else params["lm_head"]
    return jnp.dot(x.astype(COMPUTE_DTYPE), w.astype(COMPUTE_DTYPE),
                   preferred_element_type=jnp.float32)


def init_unembed(rng, d: int, vocab: int):
    return {"lm_head": _normal(rng, (d, vocab), 1.0 / math.sqrt(d))}


# ---------------------------------------------------------------------------
# Positional encodings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (b, s, h, dh); positions: (b, s) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (b, s, dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d: int) -> Array:
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(10000.0) / d))
    pe = jnp.zeros((seq_len, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(rng, d: int, d_ff: int, kind: str = "swiglu"):
    k1, k2, k3 = jax.random.split(rng, 3)
    std_in, std_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(d_ff)
    p = {"w_in": _normal(k1, (d, d_ff), std_in),
         "w_out": _normal(k3, (d_ff, d), std_out)}
    if kind in ("swiglu", "geglu"):
        p["w_gate"] = _normal(k2, (d, d_ff), std_in)
    return p


def apply_mlp(params, x: Array, kind: str = "swiglu") -> Array:
    xc = x.astype(COMPUTE_DTYPE)
    h = jnp.dot(xc, params["w_in"].astype(COMPUTE_DTYPE))
    if kind == "swiglu":
        g = jnp.dot(xc, params["w_gate"].astype(COMPUTE_DTYPE))
        h = jax.nn.silu(g) * h
    elif kind == "geglu":
        g = jnp.dot(xc, params["w_gate"].astype(COMPUTE_DTYPE))
        h = jax.nn.gelu(g) * h
    elif kind == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(f"unknown mlp kind {kind}")
    from repro.distributed.sharding import shard_act
    h = shard_act(h, "batch", None, "ff")
    return jnp.dot(h, params["w_out"].astype(COMPUTE_DTYPE))


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def softcap(x: Array, cap: Optional[float]) -> Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x.astype(jnp.float32) / cap)
